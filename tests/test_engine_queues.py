"""Execution-queue engine model (v4): multi-queue per-device dispatch,
compute-queue contention, micro-batched prefill, and the data-parallel
multi-device RealEngine.

Covers: queue-slot handout (one op in flight per queue, pinned streams
bind to their queue), the share-weighted FLOP contention model, chunked
prefill FIFO order within a queue class, replica routing + KV/handle
accounting on the real engine, the ``least_contended`` cluster policy,
threaded-pacing calibration, and the default-config regression (single
queue == the v3 engine-slot behavior, byte-for-byte)."""
import copy
import threading
import time

import numpy as np
import pytest
from conftest import drive_modes

from repro.core import Phase, connect
from repro.core.queues import parse_queue_spec, queue_key
from repro.serving import Cluster, DeploymentSpec, SimConfig, make_workload
from repro.serving.simulator import EventLoop, SimBackend, deployment_dynamic
from repro.transport import LinkModel


def _drive_all(loop, daemons):
    """Stepped driver: drain every daemon's ready set on each completion."""
    def kick_all():
        for d in daemons:
            while True:
                op = d.select_next(loop.clock.t)
                if op is None:
                    break

                def complete(o=op, dd=d):
                    dd.mark_complete(o, loop.clock.t)
                    kick_all()
                loop.after(float(op.meta.get("est_duration", 1e-3)), complete)
    return kick_all


# ------------------------------------------------------------ queue specs
def test_parse_queue_spec_forms():
    assert parse_queue_spec(None) == {"compute": 1, "copy": 1}
    assert parse_queue_spec("compute:3") == {"compute": 3, "copy": 1}
    assert parse_queue_spec({"compute": 2, "copy": 2}) == \
        {"compute": 2, "copy": 2}
    with pytest.raises(ValueError):
        parse_queue_spec("dma:2")
    with pytest.raises(ValueError):
        parse_queue_spec({"compute": 0})
    assert queue_key("compute", 1) == "compute:1"


# ------------------------------------------------------ queue-slot handout
def test_queue_slot_handout_stepped():
    """A compute x 2 device hands the stepped driver TWO compute ops
    before any completion (one per free queue); a third dispatches only
    after a slot frees."""
    loop = EventLoop()
    sess = connect(mode="sim", devices=1, backend=SimBackend(loop.clock),
                   queues={"compute": 2})
    c = sess.device(0)
    d = sess.daemon(0)
    streams = [c.create_stream(phase=Phase.PREFILL) for _ in range(3)]
    for s in streams:
        c.launch(s, None, phase=Phase.PREFILL, meta={"est_duration": 1.0})
    first = d.select_next(0.0)
    second = d.select_next(0.0)
    assert first is not None and second is not None
    assert first.meta["_queue"] != second.meta["_queue"]
    assert d.select_next(0.0) is None       # both compute queues busy
    d.mark_complete(first, 1.0)
    third = d.select_next(1.0)
    assert third is not None
    assert third.meta["_queue"] == first.meta["_queue"]  # reuses freed slot
    sess.close()


def test_queue_slot_handout_threaded():
    """Two compute queues execute two launches CONCURRENTLY on real
    threads; a third stream's launch waits for a free queue."""
    gate = threading.Event()
    started = [threading.Event() for _ in range(3)]
    with connect(mode="flex", devices=1, queues={"compute": 2}) as sess:
        streams = [sess.create_stream(phase=Phase.PREFILL) for _ in range(3)]
        futs = [sess.launch(s, lambda i=i: (started[i].set(), gate.wait(5)),
                            phase=Phase.PREFILL)
                for i, s in enumerate(streams)]
        assert started[0].wait(5) and started[1].wait(5)
        time.sleep(0.05)
        assert not started[2].is_set()      # no third compute queue
        gate.set()
        for f in futs:
            f.result(10)
        assert started[2].is_set()


def test_pinned_stream_binds_to_its_queue_stepped():
    """A stream pinned to queue 0 stays blocked while queue 0 is busy even
    though queue 1 is free; an unpinned stream takes the free queue."""
    loop = EventLoop()
    sess = connect(mode="sim", devices=1, backend=SimBackend(loop.clock),
                   queues={"compute": 2})
    c = sess.device(0)
    d = sess.daemon(0)
    s_a = c.create_stream(phase=Phase.PREFILL, queue=0)
    s_b = c.create_stream(phase=Phase.PREFILL, queue=0)
    s_c = c.create_stream(phase=Phase.DECODE, queue=1)
    c.launch(s_a, None, phase=Phase.PREFILL, meta={"est_duration": 1.0})
    c.launch(s_b, None, phase=Phase.PREFILL, meta={"est_duration": 1.0})
    c.launch(s_c, None, phase=Phase.DECODE, meta={"est_duration": 1.0})
    first = d.select_next(0.0)
    assert first.meta["_queue"] == ("compute", 0)
    nxt = d.select_next(0.0)
    # s_b is pinned to the busy queue 0 -> only the decode head is ready
    assert nxt is not None and nxt.phase == Phase.DECODE
    assert nxt.meta["_queue"] == ("compute", 1)
    assert d.select_next(0.0) is None
    d.mark_complete(first, 1.0)
    after = d.select_next(1.0)              # now s_b's head dispatches
    assert after.vstream == s_b and after.meta["_queue"] == ("compute", 0)
    sess.close()


def test_queue_binding_validation_and_rebind():
    loop = EventLoop()
    sess = connect(mode="sim", devices=1, backend=SimBackend(loop.clock),
                   queues={"compute": 2})
    c = sess.device(0)
    d = sess.daemon(0)
    with pytest.raises(ValueError):
        c.create_stream(phase=Phase.PREFILL, queue=5)
    s = c.create_stream(phase=Phase.PREFILL)
    assert d.stream_queue(s) is None
    c.bind_stream_queue(s, 1)
    assert d.stream_queue(s) == 1
    with pytest.raises(ValueError):
        c.bind_stream_queue(s, 2)
    c.bind_stream_queue(s, None)
    assert d.stream_queue(s) is None
    sess.close()


def test_queue_occupancy_in_policy_context():
    """The daemon reports per-queue occupancy (queue key -> phase)."""
    loop = EventLoop()
    sess = connect(mode="sim", devices=1, backend=SimBackend(loop.clock),
                   queues={"compute": 2})
    c = sess.device(0)
    d = sess.daemon(0)
    s = c.create_stream(phase=Phase.PREFILL)
    c.launch(s, None, phase=Phase.PREFILL, meta={"est_duration": 1.0})
    assert d.queue_occupancy() == {"compute:0": None, "compute:1": None,
                                   "copy:0": None}
    op = d.select_next(0.0)
    occ = d.queue_occupancy()
    assert occ["compute:0"] == "prefill"
    assert occ["compute:1"] is None and occ["copy:0"] is None
    d.mark_complete(op, 1.0)
    assert d.queue_occupancy()["compute:0"] is None
    sess.close()


# ----------------------------------------------- compute-share contention
def test_share_weighted_processor_sharing():
    """The FLOP contention model: a compute-bound op (share 1.0) and a
    bandwidth-bound op (share 0.25) co-located on one device each stretch
    by the total demand (1.25x), not by 2x — and a fractional-share op
    alone runs at its solo duration."""
    lm = LinkModel(bw=1.0, latency_s=0.0)
    seg = ("flops", "dev")
    # solo: work = solo_duration * share -> elapsed == solo_duration
    x = lm.start(seg, 1.0 * 0.25, 0.0, share=0.25)
    assert lm.eta(x, 0.0) == pytest.approx(1.0)
    assert lm.poll(x, 1.0)
    # co-located: total demand 1.25 -> both stretch 1.25x
    a = lm.start(seg, 1.0, 10.0, share=1.0)       # compute-bound, solo 1.0s
    b = lm.start(seg, 0.25, 10.0, share=0.25)     # bw-bound, solo 1.0s
    assert lm.eta(a, 10.0) == pytest.approx(11.25)
    assert lm.eta(b, 10.0) == pytest.approx(11.25)
    assert lm.poll(a, 11.25) and lm.poll(b, 11.25)
    # equal full shares degrade to the classic even split (2x)
    c1 = lm.start(seg, 1.0, 20.0, share=1.0)
    c2 = lm.start(seg, 1.0, 20.0, share=1.0)
    assert lm.eta(c1, 20.0) == pytest.approx(22.0)
    assert lm.eta(c2, 20.0) == pytest.approx(22.0)


@pytest.mark.parametrize("drive", drive_modes())
def test_multi_queue_cluster_completes_and_conserves(drive):
    """A compute x 2 deployment with micro-batched prefill completes its
    workload with KV conservation intact in BOTH drive modes, and the
    threaded drive surfaces its pacing calibration."""
    from repro.configs import get_config
    cfg = get_config("mixtral-8x7b")
    wl = make_workload(12, 2048, 24, rate=60.0, seed=5)
    sim = SimConfig(compute_queues=2, chunk_prefill_tokens=1024)
    kw = {} if drive == "stepped" else {"time_scale": 0.05}
    cluster = Cluster(cfg, deployment_dynamic(instances=1), sim_cfg=sim,
                      drive=drive, **kw)
    res = cluster.run(copy.deepcopy(wl), until=72000)
    cluster.check_kv_conservation()
    assert res["completed"] == 12
    assert res["queues"] == {"compute": 2, "copy": 1,
                             "chunk_prefill_tokens": 1024}
    if drive == "threaded":
        cal = res["calibration"]
        assert 0.0 <= cal["dispatch_overhead_wall_s"] <= 2e-3
        assert cal["time_scale"] == 0.05


def test_decode_tpot_improves_with_second_compute_queue():
    """The acceptance property, stepped (deterministic): under co-located
    chunked prefill, a second compute queue (decode pinned to its own
    queue) cuts decode TPOT versus the single-queue baseline at equal
    throughput."""
    from repro.configs import get_config
    cfg = get_config("mixtral-8x7b")
    wl = make_workload(40, 8192, 96, rate=40.0, seed=3)

    def run(cq):
        sim = SimConfig(compute_queues=cq, chunk_prefill_tokens=2048)
        cluster = Cluster(cfg, deployment_dynamic(instances=1), sim_cfg=sim)
        res = cluster.run(copy.deepcopy(wl), until=72000)
        cluster.check_kv_conservation()
        assert res["completed"] == 40
        return res

    base, multi = run(1), run(2)
    assert multi["tpot_mean_s"] < base["tpot_mean_s"], (base, multi)
    assert multi["tpot_p99_s"] < base["tpot_p99_s"]
    assert multi["requests_per_s"] >= 0.98 * base["requests_per_s"]


# ------------------------------------------------------ micro-batch order
def test_prefill_chunks_stay_fifo_within_queue_class():
    """Chunks of one request ride ONE stream: they dispatch and complete
    in chunk order even on a multi-queue device with other prefill work
    interleaving on the sibling queue."""
    loop = EventLoop()
    sess = connect(mode="sim", devices=1, backend=SimBackend(loop.clock),
                   queues={"compute": 2})
    c = sess.device(0)
    d = sess.daemon(0)
    s_req = c.create_stream(phase=Phase.PREFILL, queue=0)
    s_other = c.create_stream(phase=Phase.PREFILL, queue=1)
    completions = []
    for i in range(4):                       # one request's chunks
        c.launch(s_req, None, phase=Phase.PREFILL,
                 meta={"est_duration": 0.5, "chunk": i}).add_done_callback(
            lambda f, i=i: completions.append(("req", i, loop.clock.t)))
    for i in range(3):                       # a sibling request's work
        c.launch(s_other, None, phase=Phase.PREFILL,
                 meta={"est_duration": 0.7}).add_done_callback(
            lambda f, i=i: completions.append(("other", i, loop.clock.t)))
    kick = _drive_all(loop, [d])
    loop.at(0.0, kick)
    loop.run()
    req_chunks = [i for tag, i, _ in completions if tag == "req"]
    assert req_chunks == sorted(req_chunks) == [0, 1, 2, 3]
    # the sibling stream's ops really interleaved (overlap, not serial)
    req_times = [t for tag, _, t in completions if tag == "req"]
    other_times = [t for tag, _, t in completions if tag == "other"]
    assert other_times[0] < req_times[-1]
    sess.close()


def test_cluster_chunked_prefill_first_token_after_last_chunk():
    """A chunked prompt's first token arrives once ALL chunks finished:
    chunk launches model the same total work as one whole-prompt op (plus
    per-launch overhead), and the request still completes decode."""
    from repro.configs import get_config
    cfg = get_config("mixtral-8x7b")
    wl = make_workload(4, 3000, 8, rate=1e5, seed=1)
    res_whole = None
    for chunk in (0, 1000):
        sim = SimConfig(chunk_prefill_tokens=chunk)
        cluster = Cluster(cfg, deployment_dynamic(instances=1), sim_cfg=sim)
        res = cluster.run(copy.deepcopy(wl), until=72000)
        assert res["completed"] == 4
        if chunk == 0:
            res_whole = res
        else:
            # chunked prefill pays two extra launch overheads per prompt
            assert res["ttft_mean_s"] > res_whole["ttft_mean_s"]
    cluster.check_kv_conservation()


# ------------------------------------------------- default-config identity
def test_default_config_byte_identical_to_single_queue():
    """SimConfig() and an explicit compute x 1 / copy x 1 spec produce the
    IDENTICAL result dict (the queue layer adds no event-stream change at
    the default config)."""
    from repro.configs import get_config
    cfg = get_config("mixtral-8x7b")
    wl = make_workload(20, 1024, 32, rate=80.0, seed=9)

    def run(sim_cfg):
        cluster = Cluster(cfg, DeploymentSpec(
            mode="disagg", prefill_instances=2, prefill_chips=16,
            decode_instances=1, decode_chips=64), sim_cfg=sim_cfg)
        res = cluster.run(copy.deepcopy(wl), until=72000)
        cluster.check_kv_conservation()
        return res

    a = run(SimConfig())
    b = run(SimConfig(compute_queues=1, copy_queues=1,
                      chunk_prefill_tokens=0))
    assert a == b


# --------------------------------------------- replica routing (RealEngine)
@pytest.mark.slow
def test_real_engine_replicas_route_and_account():
    """Data-parallel RealEngine: R=2 replicas over one session — requests
    spread across replicas by the cluster policy, per-request outputs are
    byte-identical to the single-replica engine, and every replica's
    handle/memory tables drain to zero (KV accounting)."""
    import jax
    from repro.configs import get_config
    from repro.distributed.sharding import unbox
    from repro.models import build_model
    from repro.serving.engine import RealEngine
    from repro.serving.request import Request

    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))

    def mk():
        return [Request(prompt_len=10, max_new_tokens=6,
                        prompt_tokens=np.random.default_rng(s).integers(
                            0, cfg.vocab_size, 10).tolist(),
                        arrival_time=s * 0.01) for s in range(6)]

    outs = {}
    for tag, kw in (("r1", {}), ("r2", {"replicas": 2}),
                    ("r2q2", {"replicas": 2, "compute_queues": 2})):
        eng = RealEngine(model, params, mode="dynamic_pd", max_num_seqs=2,
                         max_len=32, **kw)
        try:
            reqs = mk()
            res = eng.run(reqs, timeout=300)
            assert res["completed"] == 6
            outs[tag] = [r.output_tokens for r in reqs]
            if kw.get("replicas", 1) > 1:
                assert {r.instance for r in reqs} == \
                    {"replica0", "replica1"}
        finally:
            eng.shutdown()
        for dev in eng.session.stats().values():   # leak-free per replica
            assert dev["buffers"] == 0 and dev["streams"] == 0
            assert dev["allocated_bytes"] == 0
    assert outs["r1"] == outs["r2"] == outs["r2q2"]


@pytest.mark.slow
def test_real_engine_disagg_replicas_kv_accounting():
    """Disagg replicas are device PAIRS: each replica's KV transfer rides
    its own pair's copy engines; outputs match single-replica dynamic and
    all four devices' tables drain (no cross-replica leaks)."""
    import jax
    from repro.configs import get_config
    from repro.distributed.sharding import unbox
    from repro.models import build_model
    from repro.serving.engine import RealEngine
    from repro.serving.request import Request

    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))

    def mk():
        return [Request(prompt_len=10, max_new_tokens=5,
                        prompt_tokens=np.random.default_rng(s).integers(
                            0, cfg.vocab_size, 10).tolist(),
                        arrival_time=s * 0.01) for s in range(4)]

    outs = {}
    for tag, kw in (("dyn", {"mode": "dynamic_pd"}),
                    ("disagg2", {"mode": "disagg", "replicas": 2,
                                 "kv_chunk_layers": 2})):
        eng = RealEngine(model, params, max_num_seqs=2, max_len=32, **kw)
        if tag == "disagg2":
            assert eng.session.device_count() == 4
        try:
            reqs = mk()
            res = eng.run(reqs, timeout=300)
            assert res["completed"] == 4
            outs[tag] = [r.output_tokens for r in reqs]
        finally:
            eng.shutdown()
        for dev in eng.session.stats().values():
            assert dev["buffers"] == 0 and dev["streams"] == 0
            assert dev["allocated_bytes"] == 0
        assert len(eng.session.shared_events) == 0
    assert outs["disagg2"] == outs["dyn"]


# ------------------------------------------------- least_contended routing
def test_least_contended_registry_and_fallback():
    from repro.sched import make_policy, policy_kind
    assert policy_kind("least_contended") == "cluster"
    pol = make_policy("least_contended")

    class Inst:
        def __init__(self, name, load):
            self.name, self._load = name, load
            self.failed, self.ewma_step = False, 0.0

        def load(self):
            return self._load

    # unbound / no topology: degrades to least-loaded
    a, b = Inst("D0", 2.0), Inst("D1", 1.0)
    assert pol.route_decode(None, Inst("P0", 0), [a, b]) is b


def test_least_contended_avoids_live_flow_path():
    """With a KV stream occupying the path to D0, route_decode prefers D1
    even though D0 is less loaded."""
    from repro.sched import make_policy
    from repro.transport import make_topology

    cfg_topo = make_topology("shared_spine", n_spines=2)

    class Inst:
        def __init__(self, name, load):
            self.name, self._load = name, load
            self.failed, self.ewma_step = False, 0.0

        def load(self):
            return self._load

    class FakeCluster:
        topology = cfg_topo
        link_model = LinkModel(latency_s=0.0, topology=cfg_topo)

    pol = make_policy("least_contended")
    pol.bind(FakeCluster())
    src = Inst("P0", 0.0)
    d0, d1 = Inst("D0", 0.0), Inst("D1", 5.0)
    # idle fabric: ties on contention -> load tiebreak picks D0
    assert pol.route_decode(None, src, [d0, d1]) is d0
    # a live transfer occupies the full P0->D0 path (incl. D0's ingress)
    FakeCluster.link_model.start(cfg_topo.path("P0", "D0"), 1e9, 0.0)
    assert pol.route_decode(None, src, [d0, d1]) is d1


# ----------------------------------------------------- pacing calibration
def test_calibrate_dispatch_overhead_bounds():
    from repro.serving.realtime import (RealTimeSimBackend, WallClock,
                                        calibrate_dispatch_overhead)
    v = calibrate_dispatch_overhead(samples=10, force=True)
    assert 0.0 <= v <= 2e-3
    backend = RealTimeSimBackend(WallClock(0.1), 0.1)
    cal = backend.calibration()
    assert cal["dispatch_overhead_wall_s"] == pytest.approx(
        backend.dispatch_overhead_s, abs=1e-7)
    assert cal["dispatch_overhead_virtual_s"] == pytest.approx(
        backend.dispatch_overhead_s / 0.1, abs=1e-6)
    # an explicit override skips the probe and is honored exactly
    b2 = RealTimeSimBackend(WallClock(0.1), 0.1, dispatch_overhead_s=1e-4)
    assert b2.dispatch_overhead_s == 1e-4
