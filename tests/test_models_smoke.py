"""Per-arch smoke tests (assignment deliverable f): reduced config of the
same family, one forward + one train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.distributed.sharding import unbox
from repro.models import build_model
from repro.training import (AdamWConfig, TrainConfig, adamw_init, make_batch,
                            make_train_step)

B, S = 2, 32


def _batch(cfg):
    return {k: jnp.asarray(v) for k, v in make_batch(cfg, B, S, seed=1).items()}


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_no_nans(arch, rng_key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = unbox(model.init(rng_key))
    batch = _batch(cfg)
    x, aux = model.forward(params, batch, remat=False)
    exp_S = S
    assert x.shape == (B, exp_S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
    logits = model._logits(params, x)
    assert logits.shape == (B, exp_S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_no_nans(arch, rng_key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = unbox(model.init(rng_key))
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=2,
                                       total_steps=10))
    opt = adamw_init(tcfg.opt, params)
    step = jax.jit(make_train_step(model, tcfg))
    batch = _batch(cfg)
    params2, opt2, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0.0 < loss < 20.0
    assert float(metrics["grad_norm"]) > 0
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_shapes(arch, rng_key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = unbox(model.init(rng_key))
    P = 16
    cache = model.init_cache(B, S, enc_len=12)
    if cfg.is_encdec:
        src = jax.random.normal(rng_key, (B, 12, cfg.d_model), jnp.bfloat16)
        tgt = jax.random.randint(rng_key, (B, P), 0, cfg.vocab_size)
        logits, cache, lengths = model.prefill(
            params, {"src_embeds": src, "tgt_tokens": tgt}, cache)
    else:
        toks = jax.random.randint(rng_key, (B, P), 0, cfg.vocab_size)
        logits, cache, lengths = model.prefill(params, {"tokens": toks}, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = model.decode(params, nxt, cache, lengths)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
