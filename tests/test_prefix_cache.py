"""Prefix-cache tier (v6): refcounted page sharing, the bucketed block
index, eviction policies, the unified registry error contract, the v5->v6
route_prefill migration, and cross-instance reuse end-to-end in BOTH
FLEX_DRIVE modes (conservation through evictions, mid-fetch faults, and
role switches included)."""
import numpy as np
import pytest
from conftest import drive_modes

from repro.cache import (NullPrefixCache, PrefixCache, list_caches,
                         make_cache, request_block_hashes)
from repro.cache.index import block_hashes
from repro.registry import UnknownNameError
from repro.serving.kvcache import OutOfPages, PagedAllocator
from repro.serving.request import Request


# =====================================================================
# PagedAllocator: refcounted sharing
# =====================================================================

def test_allocator_shared_prefix_counts_once():
    a = PagedAllocator(num_pages=8, page_size=64)
    p1 = a.allocate(1, 256)                      # 4 pages
    p2 = a.allocate(2, 256, shared=p1[:2])       # 2 shared + 2 fresh
    assert p2[:2] == p1[:2]
    assert a.used_pages == 6                     # shared pages count ONCE
    assert a.shared_pages() == 2
    assert a.ref_count(p1[0]) == 2
    a.check_invariants()


def test_allocator_free_keeps_live_refs():
    a = PagedAllocator(num_pages=8, page_size=64)
    p1 = a.allocate(1, 128)
    a.allocate(2, 128, shared=p1)
    # freeing table 1 releases NOTHING: table 2 still references both pages
    assert a.free(1) == 0
    assert a.used_pages == 2
    a.check_invariants()
    assert a.free(2) == 2                        # last refs go -> released
    assert a.used_pages == 0
    a.check_invariants()


def test_allocator_pin_blocks_release():
    a = PagedAllocator(num_pages=4, page_size=64)
    pages = a.allocate(1, 128)
    a.pin(pages[0])
    assert a.free(1) == 1                        # only the unpinned page
    assert a.used_pages == 1
    a.check_invariants()
    assert a.unpin(pages[0]) is True             # last reference released
    assert a.used_pages == 0
    with pytest.raises(KeyError):
        a.unpin(pages[0])
    with pytest.raises(KeyError):
        a.pin(pages[0])                          # cannot pin a free page


def test_allocator_shared_must_be_owned():
    a = PagedAllocator(num_pages=4, page_size=64)
    with pytest.raises(KeyError, match="not owned"):
        a.allocate(1, 64, shared=[3])
    a.allocate(1, 192)
    with pytest.raises(OutOfPages):
        a.allocate(2, 192)                       # only 1 free page left
    a.check_invariants()


# =====================================================================
# Block index: chained page-aligned hashing
# =====================================================================

def test_block_hashes_chained_and_page_aligned():
    t = np.arange(200, dtype=np.int64)
    h = block_hashes(t, 64)
    assert len(h) == 3                           # partial tail not indexed
    # chain property: equal prefixes share keys, divergence breaks ALL
    # later keys even when a later block's bytes match
    t2 = t.copy()
    t2[0] += 1
    h2 = block_hashes(t2, 64)
    assert h2[0] != h[0] and h2[1] != h[1] and h2[2] != h[2]
    assert block_hashes(t[:128], 64) == h[:2]


def test_request_block_hashes_memoized_and_capped():
    toks = np.arange(300, dtype=np.int32)
    r = Request(prompt_len=200, max_new_tokens=1, prompt_tokens=toks)
    h = request_block_hashes(r, 64)
    assert len(h) == 3                           # capped at prompt_len
    assert request_block_hashes(r, 64) is h      # memo hit
    assert request_block_hashes(
        Request(prompt_len=100, max_new_tokens=1), 64) == ()


# =====================================================================
# PrefixCache: match / acquire / insert / evict
# =====================================================================

def _req(tokens, prompt_len=None):
    arr = np.asarray(tokens, dtype=np.int32)
    return Request(prompt_len=prompt_len or len(arr), max_new_tokens=1,
                   prompt_tokens=arr)


def test_cache_match_and_usable_cap():
    c = PrefixCache(capacity_tokens=1024, page_tokens=64)
    r1 = _req(np.arange(256))
    assert c.acquire(r1, now=0.0) == 0           # cold
    c.release(r1)
    c.insert(r1, now=0.0)
    assert c.tokens() == 256
    # identical prompt: full match, capped at prompt_len - 1
    r2 = _req(np.arange(256))
    assert c.acquire(r2, now=1.0) == 255
    c.release(r2)
    # longer prompt sharing the head: matches the indexed 4 pages
    r3 = _req(np.arange(512))
    assert c.acquire(r3, now=2.0) == 256
    c.release(r3)
    c.check_invariants()
    s = c.stats()
    assert s["requests"] == 3 and s["request_hits"] == 2
    assert 0.0 <= s["hit_rate"] <= 1.0


def test_cache_pinned_blocks_survive_eviction():
    c = PrefixCache(capacity_tokens=256, page_tokens=64)    # 4 pages
    r1 = _req(np.arange(256))
    c.insert(r1, now=0.0)
    r2 = _req(np.arange(256))
    assert c.acquire(r2, now=1.0) == 255         # pins all 4 blocks
    # a different chain wants room: nothing is evictable while pinned
    assert c.insert(_req(np.arange(1000, 1256)), now=2.0) == 0
    assert c.stats()["insert_skips"] == 1
    c.release(r2)
    # unpinned now: leaf-first eviction makes room
    assert c.insert(_req(np.arange(1000, 1256)), now=3.0) == 4
    assert c.stats()["evictions"] == 4
    c.check_invariants()


def test_cache_leaf_only_eviction_order():
    c = PrefixCache(capacity_tokens=256, page_tokens=64)
    c.insert(_req(np.arange(256)), now=0.0)      # chain of 4
    # evicting one page must take the LEAF (last block), never the root
    assert c.evict_tokens(1, now=1.0) == 64
    r = _req(np.arange(256))
    assert c.acquire(r, now=2.0) == 192          # head 3 blocks survive
    c.release(r)
    c.check_invariants()


def test_cache_lru_vs_lfu_victim():
    for policy, expect_survivor in (("lru", "hot_recent"),
                                    ("lfu", "hot_frequent")):
        c = make_cache(policy, capacity_tokens=128, page_tokens=64)
        a, b = _req([1] * 64), _req([2] * 64)
        c.insert(a, now=0.0)
        c.insert(b, now=1.0)
        if policy == "lfu":
            for t in (2.0, 3.0):                 # a is frequent, b recent
                c.acquire(a, now=t)
                c.release(a)
            c.acquire(b, now=4.0)
            c.release(b)
            survivor, victim = a, b              # fewer hits evicts first
        else:
            c.acquire(a, now=5.0)                # a is most recent
            c.release(a)
            survivor, victim = a, b
        c.evict_tokens(1, now=6.0)
        assert c.match_tokens(survivor) == 64, (policy, expect_survivor)
        assert c.match_tokens(victim) == 0


def test_cache_ttl_expiry_and_sweep():
    c = make_cache("ttl", ttl_s=5.0, capacity_tokens=1024, page_tokens=64)
    c.insert(_req(np.arange(128)), now=0.0)
    assert c.sweep(now=4.0) == 0
    assert c.sweep(now=10.0) == 2                # both blocks expired
    assert c.stats()["expired"] == 2
    assert c.tokens() == 0


def test_cache_insert_chain_orphan_skip():
    c = PrefixCache(capacity_tokens=1024, page_tokens=64)
    h = block_hashes(np.arange(256, dtype=np.int64), 64)
    # a fetch landed blocks [2:4] but the local head [0:2] was evicted
    # mid-flight: the tail is orphaned, nothing is inserted
    assert c.insert_chain(h, now=0.0, have_from=2) == 0
    assert c.stats()["orphan_skips"] == 1
    assert c.tokens() == 0
    # with the head present the same call grafts the tail
    c.insert_chain(h[:2], now=1.0)
    assert c.insert_chain(h, now=2.0, have_from=2) == 2
    assert c.match_chain(h) == 256
    c.check_invariants()


def test_cache_pin_chain_all_or_nothing():
    c = PrefixCache(capacity_tokens=1024, page_tokens=64)
    h = block_hashes(np.arange(192, dtype=np.int64), 64)
    c.insert_chain(h, now=0.0)
    assert c.pin_chain(h) is True
    assert c.evict_tokens(999, now=1.0) == 0     # everything pinned
    c.unpin_chain(h)
    missing = h + (12345,)
    assert c.pin_chain(missing) is False         # no partial pins taken
    assert c.evict_tokens(999, now=2.0) == 192   # so nothing stayed pinned
    c.check_invariants()


def test_cache_clear_keeps_counters_and_tolerates_stale_handles():
    c = PrefixCache(capacity_tokens=1024, page_tokens=64)
    r = _req(np.arange(128))
    c.insert(r, now=0.0)
    c.acquire(r, now=1.0)
    before = c.stats()["inserts"]
    c.clear()                                    # instance fault
    assert c.tokens() == 0
    assert c.stats()["inserts"] == before        # cumulative telemetry
    c.release(r)                                 # stale pin handle: no-op
    c.unpin_chain(block_hashes(np.arange(128, dtype=np.int64), 64))
    c.check_invariants()


def test_cache_room_fn_gates_inserts():
    room = {"free": 0}
    c = PrefixCache(capacity_tokens=1024, page_tokens=64,
                    room_fn=lambda: room["free"])
    assert c.insert(_req(np.arange(128)), now=0.0) == 0   # no KV headroom
    room["free"] = 1 << 20
    assert c.insert(_req(np.arange(128)), now=1.0) == 2


def test_cache_on_delta_ledger_hook():
    ledger = {"kv": 0}

    def delta(d):
        ledger["kv"] += d

    c = PrefixCache(capacity_tokens=256, page_tokens=64, on_delta=delta)
    c.insert(_req(np.arange(256)), now=0.0)
    assert ledger["kv"] == 256
    c.evict_tokens(256, now=1.0)
    assert ledger["kv"] == 0


# =====================================================================
# Unified registries (satellite a): one error contract across all four
# =====================================================================

def test_make_cache_registry():
    assert set(list_caches()) >= {"none", "lru", "lfu", "ttl"}
    assert isinstance(make_cache("none"), NullPrefixCache)
    assert make_cache("lfu", capacity_tokens=128).name == "lfu"
    assert make_cache("ttl", ttl_s=2.0).policy.ttl_s == 2.0


@pytest.mark.parametrize("kind,factory", [
    ("policy", lambda n, **k: __import__(
        "repro.sched", fromlist=["make_policy"]).make_policy(n, **k)),
    ("topology", lambda n, **k: __import__(
        "repro.transport", fromlist=["make_topology"]).make_topology(
            n, **k)),
    ("traffic", lambda n, **k: __import__(
        "repro.traffic", fromlist=["make_traffic"]).make_traffic(n, **k)),
    ("cache", lambda n, **k: make_cache(n, **k)),
])
def test_registries_unified_error_contract(kind, factory):
    """All four ``make_*`` registries raise the SAME unknown-name error
    shape — an ``UnknownNameError`` that is a ``ValueError`` (and, for
    the migration window, a ``KeyError``) whose message names the kind
    and lists what IS registered — and ``TypeError`` on unknown knobs
    naming the accepted set."""
    with pytest.raises(ValueError, match=f"unknown {kind}") as ei:
        factory("definitely_not_registered")
    assert isinstance(ei.value, UnknownNameError)
    assert isinstance(ei.value, KeyError)        # migration window
    assert "registered:" in str(ei.value)
    known = {"policy": "fifo", "topology": "flat",
             "traffic": "open_loop", "cache": "lru"}[kind]
    with pytest.raises(TypeError, match="accepts knobs"):
        factory(known, bogus_knob_xyz=1)


# =====================================================================
# route_prefill v5 -> v6 adapter: REMOVED in v9 (one-release window over)
# =====================================================================

def test_two_arg_route_prefill_adapter_removed():
    """The v5 two-argument compatibility adapter is gone: neither the
    package nor the defining module exports ``dispatch_route_prefill``
    anymore, and the layering linter bans re-importing it (the ban-list
    is what keeps an expired shim from quietly returning)."""
    import repro.sched
    import repro.sched.cluster
    assert not hasattr(repro.sched, "dispatch_route_prefill")
    assert not hasattr(repro.sched.cluster, "dispatch_route_prefill")
    assert "dispatch_route_prefill" not in repro.sched.__all__
    from repro.analysis.layering import BANNED_FROM_IMPORTS
    assert ("repro.sched", "dispatch_route_prefill") in BANNED_FROM_IMPORTS
    assert ("repro.sched.cluster",
            "dispatch_route_prefill") in BANNED_FROM_IMPORTS


def test_prefix_affinity_policy_unit():
    from repro.sched import PrefixAffinityPolicy, RouteContext, make_policy

    class FakeInst:
        def __init__(self, name, load):
            self.name, self._load = name, load
            self.failed, self.ewma_step = False, 0.0

        def load(self):
            return self._load

    pool = [FakeInst("A", 5.0), FakeInst("B", 0.0)]
    p = make_policy("prefix_affinity")
    assert isinstance(p, PrefixAffinityPolicy)
    # match >= one page on the BUSIER instance: affinity wins over load
    ctx = RouteContext(match_tokens={"A": 128, "B": 0}, page_tokens=64)
    assert p.route_prefill(None, pool, ctx).name == "A"
    # sub-page match: degrade to load-based routing
    ctx2 = RouteContext(match_tokens={"A": 32, "B": 0}, page_tokens=64)
    assert p.route_prefill(None, pool, ctx2).name == "B"
    # no context at all (legacy caller): still routes
    assert p.route_prefill(None, pool).name == "B"
    st = p.debug_state()
    assert st["affinity_routes"] == 1 and st["fallback_routes"] == 2


# =====================================================================
# End-to-end: reuse in the cluster, both drives
# =====================================================================

def _cluster(drive, cache="lru", policy="prefix_affinity", instances=2,
             **sim_knobs):
    from repro.configs import get_config
    from repro.serving import Cluster, SimConfig, deployment_dynamic
    cfg = get_config("mixtral-8x7b")
    sc = SimConfig(prefix_cache=cache, prefix_page_tokens=64, **sim_knobs)
    deploy = deployment_dynamic(total=48 * instances, instances=instances)
    deploy.cluster_policy = policy
    return Cluster(cfg, deploy, sim_cfg=sc, drive=drive, time_scale=0.01)


@pytest.mark.parametrize("drive", drive_modes())
def test_cluster_prefix_reuse_end_to_end(drive):
    """Shared-prefix traffic through a cached cluster: hits happen, FLOPs
    are saved, affinity routes conversations to their cache, and KV
    conservation holds at sampled mid-run instants."""
    from repro.traffic import make_traffic
    cl = _cluster(drive, instances=3)
    wl = make_traffic("multi_turn", n=60, rate=60.0, conversations=4,
                      seed=7)
    for t in (0.1, 0.4, 0.9, 1.6):
        cl.loop.at(t, cl.check_kv_conservation)
    out = cl.run(wl)
    cl.check_kv_conservation()
    for inst in cl.instances:
        inst.cache.check_invariants()
    assert out["failed"] == 0 and out["completed"] == 60
    pc = out["prefix_cache"]
    assert pc["hit_rate"] > 0.2
    assert pc["flops_saved"] > 0
    assert pc["matched_tokens"] <= pc["prompt_tokens"]
    assert out["policy"]["cluster"]["affinity_routes"] > 0


@pytest.mark.parametrize("drive", drive_modes())
def test_cluster_remote_prefix_fetch(drive):
    """A request routed to an instance whose peer holds a longer match
    fetches the blocks over the KV path instead of recomputing: fetch
    bytes flow, the destination serves the match, and conservation holds
    mid-fetch."""
    X = np.arange(4096, dtype=np.int32)
    Y = np.arange(10_000, 18_192, dtype=np.int32)
    reqs = [
        Request(prompt_len=4096, max_new_tokens=4, arrival_time=0.0,
                prompt_tokens=X),
        # filler keeps C0's queue visibly busy at t=1.0 so the reused
        # prompt routes to C1 (its only match source is then remote)
        Request(prompt_len=8192, max_new_tokens=4, arrival_time=1.0,
                prompt_tokens=Y),
        Request(prompt_len=4096, max_new_tokens=4, arrival_time=1.001,
                prompt_tokens=X),
    ]
    cl = _cluster(drive, policy="least_loaded", chunk_prefill_tokens=1024)
    for t in (1.002, 1.004, 1.01, 1.05):
        cl.loop.at(t, cl.check_kv_conservation)
    out = cl.run(reqs)
    cl.check_kv_conservation()
    assert out["failed"] == 0
    pc = out["prefix_cache"]
    assert pc["remote_fetches"] >= 1
    assert pc["remote_fetch_tokens"] >= 4096
    assert pc["remote_fetch_bytes"] > 0
    assert reqs[2].cached_tokens >= 4095


@pytest.mark.parametrize("drive", drive_modes())
def test_cluster_eviction_under_pressure_tiny_cache(drive):
    """A cache sized to ONE page churns constantly (insert -> evict) under
    multi-conversation traffic; accounting and conservation survive the
    churn in both drives."""
    from repro.traffic import make_traffic
    cl = _cluster(drive, instances=2, prefix_cache_frac=1e-6)
    for inst in cl.instances:
        assert inst.cache.capacity_pages == 1
    wl = make_traffic("multi_turn", n=30, rate=60.0, conversations=3,
                      seed=11)
    for t in (0.1, 0.3, 0.7):
        cl.loop.at(t, cl.check_kv_conservation)
    out = cl.run(wl)
    cl.check_kv_conservation()
    for inst in cl.instances:
        inst.cache.check_invariants()
        assert inst.cache.tokens() <= 64
    assert out["failed"] == 0 and out["completed"] == 30


@pytest.mark.parametrize("drive", drive_modes())
def test_cluster_instance_fault_clears_cache(drive):
    """Killing an instance mid-run wipes its cache with its ledger; the
    survivors keep serving (requests re-route and recompute) and
    conservation holds through the fault."""
    from repro.traffic import make_traffic
    cl = _cluster(drive, instances=3)
    wl = make_traffic("multi_turn", n=40, rate=80.0, conversations=4,
                      seed=5)
    cl.loop.at(0.25, lambda: cl.fail_instance("C1"))
    cl.loop.at(0.26, cl.check_kv_conservation)
    cl.loop.at(0.6, cl.check_kv_conservation)
    out = cl.run(wl)
    cl.check_kv_conservation()
    dead = next(i for i in cl.instances if i.name == "C1")
    assert dead.cache.tokens() == 0
    assert out["completed"] + out["failed"] == 40
    assert out["completed"] >= 35       # survivors absorbed the work


def test_cache_off_is_bit_compatible_with_v5():
    """prefix_cache='none' must not change a single event: same summary
    as a run with the knob entirely absent (the v5 contract)."""
    from repro.configs import get_config
    from repro.serving import Cluster, SimConfig, deployment_dynamic
    from repro.traffic import make_traffic
    cfg = get_config("mixtral-8x7b")
    wl1 = make_traffic("multi_turn", n=20, rate=40.0, seed=3)
    wl2 = make_traffic("multi_turn", n=20, rate=40.0, seed=3)
    outs = []
    for wl in (wl1, wl2):
        cl = Cluster(cfg, deployment_dynamic(total=96, instances=2),
                     sim_cfg=SimConfig(prefix_cache="none"))
        o = cl.run(wl)
        o.pop("policy")
        outs.append(o)
    assert "prefix_cache" not in outs[0]
    assert outs[0] == outs[1]
