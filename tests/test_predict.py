"""Predictive-scheduling model layer (v9): fits, sketches, registry.

What is pinned down here:
  * LatencyModel fits are DETERMINISTIC (same samples -> same weights,
    bit for bit) and every fit attaches a finite calibration report.
  * tau turns the ridge fit into a quantile predictor whose training
    over-prediction rate actually tracks tau.
  * invert_tokens is the real inverse of predict at fixed context.
  * QuantileSketch quantiles are MONOTONE in q under streaming updates —
    any prefix of any stream (the property the chunk adapter and JBSQ
    rely on when they compare predictions).
  * LengthPredictor sharpens per-(class, tenant) and never predicts 0.
  * make_predictor follows the unified registry contract: the same
    UnknownNameError / strict-knob TypeError shapes as make_policy.
  * to_dict/from_dict round-trips a fitted model exactly.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.predict import (LatencyModel, LengthPredictor, OpSample,
                           QuantileSketch, list_predictors, make_predictor,
                           samples_from_events)
from repro.registry import UnknownNameError


def _samples(n=200, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        t = float(rng.integers(32, 4096))
        c = t * float(rng.uniform(1.0, 3.0))
        # linear-ish ground truth + mild noise: what a roofline looks like
        dur = 1e-4 + 2e-6 * t + 3e-8 * t * c / 1e3 + rng.uniform(0, 1e-5)
        out.append(OpSample("prefill", t, c, dur))
        b = float(rng.integers(1, 64))
        out.append(OpSample("decode", b, c, 5e-4 + 1e-5 * b))
    return out


# ------------------------------------------------------------ latency fits
def test_latency_fit_deterministic_and_calibrated():
    s = _samples()
    m1, m2 = LatencyModel(), LatencyModel()
    m1.fit(s)
    m2.fit(s)
    for phase in ("prefill", "decode"):
        assert np.array_equal(m1._w[phase], m2._w[phase])
        cal = m1.calibration[phase]
        assert cal["n"] > 0
        assert np.isfinite(cal["mape"]) and 0.0 <= cal["mape"] < 5.0
        assert np.isfinite(cal["p90_err"])
    assert "overall" in m1.calibration
    # near-linear ground truth: the interaction-feature fit is tight
    assert m1.calibration["overall"]["mape"] < 0.1
    p = m1.predict("prefill", 1024, 1024)
    assert p is not None and p > 0
    assert m1.predict("no_such_phase", 1, 1) is None


def test_latency_quantile_shift_overpredicts():
    s = _samples()
    hi = LatencyModel(tau=0.9)
    hi.fit(s)
    y = np.array([x.duration_s for x in s if x.phase == "prefill"])
    pred = np.array([hi.predict("prefill", x.tokens, x.ctx)
                     for x in s if x.phase == "prefill"])
    # tau=0.9: ~90% of training ops run no slower than predicted
    assert (pred >= y).mean() >= 0.85
    with pytest.raises(ValueError, match="tau"):
        LatencyModel(tau=1.5)


def test_invert_tokens_inverts_predict():
    m = LatencyModel()
    m.fit(_samples())
    ctx = 2048.0
    target = m.predict("prefill", 777.0, ctx)
    toks = m.invert_tokens("prefill", target, ctx)
    assert toks is not None
    assert m.predict("prefill", toks, ctx) == pytest.approx(target, rel=1e-6)
    assert m.invert_tokens("unfitted_phase", 0.1, ctx) is None


def test_online_observe_tracks_errors():
    m = LatencyModel()
    m.fit(_samples())
    r0 = m.report()
    assert r0["n"] == 0 and "fit" in r0
    m.observe("prefill", 512, 512, 10.0)   # gross under-prediction
    m.observe("prefill", 512, 512, 1e-6)   # gross over-prediction
    r = m.report()
    assert r["n"] == 2 and r["over"] == 1 and r["under"] == 1
    assert np.isfinite(r["mape"]) and np.isfinite(r["p90_err"])


def test_serialization_round_trip():
    m = LatencyModel(tau=0.5)
    m.fit(_samples())
    m2 = LatencyModel.from_dict(m.to_dict())
    for t, c in ((64, 64), (1024, 2048), (4096, 8192)):
        assert m2.predict("prefill", t, c) == m.predict("prefill", t, c)
        assert m2.predict("decode", t, c) == m.predict("decode", t, c)
    assert m2.calibration == m.calibration


def test_fit_from_trace_events():
    events = [
        {"ph": "X", "name": "prefill:op", "dur": 1000.0,
         "args": {"tokens": 256, "ctx": 256}},
        {"ph": "X", "name": "prefill:op", "dur": 2000.0,
         "args": {"tokens": 512, "ctx": 512}},
        {"ph": "M", "name": "meta"},                      # ignored
        {"ph": "X", "name": "decode:op", "dur": 500.0,
         "args": {"tokens": 8, "ctx": 1024}},
        {"ph": "X", "name": "prefill:op", "dur": 0.0,     # ignored (dur<=0)
         "args": {"tokens": 64}},
    ]
    got = samples_from_events(events)
    assert [s.phase for s in got] == ["prefill", "prefill", "decode"]
    assert got[0].duration_s == pytest.approx(1e-3)   # us -> s
    with pytest.raises(ValueError, match="no training samples"):
        LatencyModel().fit([])


# -------------------------------------------------------------- the sketch
def test_quantile_sketch_monotone_under_streaming():
    rng = np.random.default_rng(7)
    sk = QuantileSketch(lo=1.0, hi=4096.0, bins=32)
    stream = rng.lognormal(4.0, 1.0, size=500)
    qs = np.linspace(0.05, 1.0, 20)
    for i, x in enumerate(stream):
        sk.update(float(x))
        if i % 50 == 0:    # any prefix of the stream: monotone in q
            vals = [sk.quantile(q) for q in qs]
            assert all(a <= b for a, b in zip(vals, vals[1:]))
    # conservative: never under-reports by more than one log-bin
    assert sk.quantile(1.0) >= float(stream.max()) * 0.99
    assert QuantileSketch().quantile(0.5) == 0.0   # empty


def test_length_predictor_sharpens_per_key():
    lp = LengthPredictor(min_count=4, default_len=100)
    assert lp.predict("chat", "t0") == 100.0      # cold start
    for _ in range(10):
        lp.observe("chat", "t0", 32)
        lp.observe("summarize", "t1", 2000)
    short = lp.predict("chat", "t0")
    long = lp.predict("summarize", "t1")
    assert short < long
    assert short >= 32                            # upper-edge conservative
    # unseen key falls back to the global sketch, never 0
    assert lp.predict("rag", "t9") > 0
    r = lp.report()
    assert r["n"] == 20 and r["keys"] == 2
    lp.observe("chat", "t0", 0)                   # ignored
    assert lp.report()["n"] == 20
    with pytest.raises(ValueError, match="q must be"):
        LengthPredictor(q=0.0)


# -------------------------------------------------------------- registry
def test_make_predictor_registry_contract():
    names = list_predictors()
    assert {"ridge_latency", "quantile_latency",
            "length_quantile"} <= set(names)
    with pytest.raises(ValueError, match="unknown predictor") as ei:
        make_predictor("definitely_not_registered")
    assert isinstance(ei.value, UnknownNameError)
    assert "registered:" in str(ei.value)
    with pytest.raises(TypeError, match="accepts knobs"):
        make_predictor("ridge_latency", bogus_knob=1)
    assert make_predictor("quantile_latency").tau == 0.9
    assert make_predictor("length_quantile", q=0.9).q == 0.9
