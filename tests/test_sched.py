"""Control-plane API v3 (repro.sched): registry resolution, PolicyContext,
admission parity between the real engine and the simulator, and dynamic
role-switching (drain correctness + the headline win) in both drive modes."""
import copy

import pytest
from conftest import drive_modes, timing_slack

from repro.core import Phase, connect
from repro.sched import (AdmissionView, DispatchPolicy, DynamicPDPolicy,
                         FIFOPolicy, GatedAdmission, LeastLoadedPolicy,
                         PolicyContext, RoleSwitchPolicy, SchedulerPolicy,
                         UngatedAdmission, list_policies, make_policy,
                         policy_kind)
from repro.serving import (Cluster, SimConfig, bursty_phase_shift,
                           deployment_6p2d, deployment_role_switch)
from repro.serving.request import RequestState


# ------------------------------------------------------------------ registry
def test_registry_resolves_every_layer():
    assert policy_kind("dynamic_pd") == "dispatch"
    assert policy_kind("gated") == "admission"
    assert policy_kind("role_switch") == "cluster"
    assert isinstance(make_policy("fifo"), FIFOPolicy)
    assert isinstance(make_policy("least_loaded"), LeastLoadedPolicy)
    assert isinstance(make_policy("ungated"), UngatedAdmission)
    pol = make_policy("dynamic_pd", ttft_guard_s=0.07, decode_share=0.3)
    assert isinstance(pol, DynamicPDPolicy)
    assert pol.cfg.ttft_guard_s == 0.07 and pol.decode_share == 0.3
    rs = make_policy("role_switch", ttft_hi_s=2.0, min_decode=2)
    assert isinstance(rs, RoleSwitchPolicy)
    assert rs.cfg.ttft_hi_s == 2.0 and rs.cfg.min_decode == 2
    assert set(list_policies("dispatch")) >= {"fifo", "static_slice",
                                              "dynamic_pd"}


def test_registry_rejects_unknown_names_and_knobs():
    with pytest.raises(KeyError, match="unknown policy"):
        make_policy("nope")
    with pytest.raises(TypeError, match="knobs"):
        make_policy("dynamic_pd", not_a_knob=1)
    ts = make_policy("static_slice", decode_share=0.8)
    assert ts.decode_share == 0.8


def test_dispatch_base_class_alias():
    # the v2 name must keep working for isinstance checks and subclasses
    assert SchedulerPolicy is DispatchPolicy
    # the repro.core.scheduler deprecation shim's one-release window ended
    # with PR 3: the module is gone, not silently redirecting
    with pytest.raises(ModuleNotFoundError):
        import repro.core.scheduler  # noqa: F401


# ------------------------------------------------------------ PolicyContext
def test_policy_context_reaches_new_style_policies():
    """Daemon-built contexts expose engine occupancy to pick(ctx); a
    hand-built PolicyContext over a plain dict of deques drives the same
    policy (the test-harness convention)."""
    seen = {}

    class Probe(DispatchPolicy):
        def pick(self, ctx):
            seen["free"] = dict(ctx.engine_free)
            seen["slots"] = dict(ctx.engine_slots)
            seen["backlog"] = ctx.backlog(Phase.PREFILL)
            for ph in (Phase.OTHER, Phase.PREFILL, Phase.DECODE):
                if ctx.get(ph):
                    return ph
            return None

    from repro.core import FlexClient, FlexDaemon

    class Tick:
        def now(self):
            return 0.0

        def estimate(self, op):
            return 1e-3

    d = FlexDaemon(0, Tick(), Probe())
    c = FlexClient(d)
    s = c.create_stream(phase=Phase.PREFILL)
    for _ in range(3):
        c.launch(s, None, phase=Phase.PREFILL)
    op = d.select_next(0.0)
    assert op is not None
    assert seen["backlog"] == 3
    assert seen["slots"] == {"compute": 1, "copy": 1}
    assert seen["free"] == {"compute": 1, "copy": 1}
    # with the compute slot occupied, the context reports no free slot
    assert d.select_next(0.0) is None
    assert seen["free"]["compute"] == 0

    # direct-call convention: a context over a plain dict of deques
    from collections import deque
    from repro.core.api import OpDescriptor, OpType
    queues = {Phase.PREFILL: deque([OpDescriptor(OpType.LAUNCH,
                                                 phase=Phase.PREFILL)]),
              Phase.DECODE: deque(), Phase.OTHER: deque()}
    assert Probe().select(PolicyContext(queues=queues)) == Phase.PREFILL


def test_policy_context_link_stats_lazy():
    calls = []
    ctx = PolicyContext(queues={}, link_stats_fn=lambda: calls.append(1) or
                        {"transfers": 7})
    assert not calls                      # lazy: nothing until read
    assert ctx.link_stats["transfers"] == 7 and calls == [1]
    assert PolicyContext(queues={}).link_stats == {}


# ----------------------------------------------------------------- admission
def test_admission_parity_same_view_same_decision():
    """ONE policy object answers for both runtimes: identical views must
    produce identical decisions regardless of which engine built them."""
    gated = GatedAdmission()
    view = AdmissionView(waiting=1, next_prompt_len=16, active=1,
                         decode_pending=1, prefilling=1, max_num_seqs=4)
    assert gated.admit(view)
    full = AdmissionView(waiting=1, next_prompt_len=16, active=2,
                         decode_pending=1, prefilling=1, max_num_seqs=4)
    assert not gated.admit(full)
    # the simulator's historical gate ignores prefilling (KV accounting
    # bounds prefill concurrency there) — explicit, not copy-pasted drift
    assert GatedAdmission(count_prefilling=False).admit(full)
    # KV gating only binds when the caller accounts tokens
    kv = AdmissionView(waiting=1, next_prompt_len=100, active=0,
                       decode_pending=0, prefilling=0, max_num_seqs=4,
                       kv_free=64)
    assert not gated.admit(kv)
    assert gated.admit(
        AdmissionView(waiting=1, next_prompt_len=100, active=0,
                      decode_pending=0, prefilling=0, max_num_seqs=4,
                      kv_free=None))
    assert not UngatedAdmission().admit(
        AdmissionView(waiting=0, next_prompt_len=0, active=9,
                      decode_pending=9, prefilling=9, max_num_seqs=1))


def test_engine_and_sim_build_equivalent_views():
    """The two runtimes' AdmissionViews use the same fields with the same
    meaning; the gated sim instance never exceeds its slot bound."""
    from repro.configs import get_config
    from repro.serving import DeploymentSpec, make_workload
    sim = SimConfig(max_num_seqs=4)
    cluster = Cluster(get_config("qwen2-vl-2b"),
                      DeploymentSpec(mode="static_colocate",
                                     colocated_instances=1,
                                     colocated_chips=4), sim_cfg=sim)
    inst = cluster.instances[0]
    assert isinstance(inst.admission, GatedAdmission)
    wl = make_workload(12, 64, 8, rate=20.0, seed=0)
    peak = {"active": 0, "gated": 0}

    def sample():
        v = inst._admission_view()
        peak["active"] = max(peak["active"], v.active)
        # the slot bound the gate protects: decoding sequences
        assert v.active <= sim.max_num_seqs
        # decision-level parity: with slots full the shared policy refuses,
        # exactly as it would for the real engine's view
        if v.active + v.decode_pending >= sim.max_num_seqs:
            v2 = AdmissionView(waiting=1, next_prompt_len=1,
                               active=v.active,
                               decode_pending=v.decode_pending,
                               prefilling=v.prefilling,
                               max_num_seqs=v.max_num_seqs,
                               kv_free=v.kv_free)
            assert not inst.admission.admit(v2)
            peak["gated"] += 1
    for t in [0.01 * i for i in range(1, 400)]:
        cluster.loop.at(t, sample)
    res = cluster.run(copy.deepcopy(wl), until=36000)
    assert res["completed"] == 12
    assert peak["active"] == sim.max_num_seqs    # the gate binds...
    assert peak["gated"] > 0                     # ...and refuses when full


# ------------------------------------------------------------- role switching
def _bursty(n_prefill=150, n_decode=40):
    return bursty_phase_shift(n_bursts=2, burst_gap_s=12.0,
                              n_prefill=n_prefill, prefill_rate=600.0,
                              prefill_io=(4096, 64), n_decode=n_decode,
                              decode_rate=8.0, decode_io=(128, 512), seed=5)


def _role_cluster(drive):
    from repro.configs import get_config
    return Cluster(get_config("mixtral-8x7b"),
                   deployment_role_switch(ttft_hi_s=0.5, ttft_lo_s=0.2,
                                          cooldown_s=2.0),
                   sim_cfg=SimConfig(prefill_window=4), drive=drive,
                   time_scale=0.1)


@pytest.mark.slow
@pytest.mark.parametrize("drive", drive_modes())
def test_role_switch_drain_correctness(drive):
    """KV conservation holds THROUGH role flips (decode drain migrates KV
    over the copy-engine path; pages stay charged at the source until each
    copy lands), in both drive modes, and every request completes."""
    cluster = _role_cluster(drive)
    wl = _bursty()
    samples = {"n": 0}

    def check():
        cluster.check_kv_conservation()
        samples["n"] += 1
    for i in range(1, 240):
        cluster.loop.at(0.25 * i, check)
    res = cluster.run(copy.deepcopy(wl), until=36000)
    assert samples["n"] > 50
    assert res["completed"] == len(wl)
    assert all(r.state == RequestState.DONE for r in cluster.requests)
    assert res["policy"]["role_flips"] >= 2          # borrowed and returned
    assert res["policy"]["cluster"]["borrowed_now"] == 0
    assert {i.role for i in cluster.decode_pool} == {"decode"}
    cluster.check_kv_conservation()
    assert not cluster.inflight_transfers
    assert all(i.kv_in_transit == 0 for i in cluster.instances)


@pytest.mark.slow
def test_role_switch_beats_static_6p2d_stepped():
    """The headline: on the bursty phase-shifted workload, dynamic role
    switching matches static 6P2D throughput with a (much) lower p95 TTFT.
    Stepped drive — fully deterministic, so the bound is strict."""
    from repro.configs import get_config
    wl = _bursty()
    res = {}
    for name, deploy in [("static", deployment_6p2d()),
                         ("switch", deployment_role_switch(
                             ttft_hi_s=0.5, ttft_lo_s=0.2, cooldown_s=2.0))]:
        cluster = Cluster(get_config("mixtral-8x7b"), deploy,
                          sim_cfg=SimConfig(prefill_window=4))
        res[name] = cluster.run(copy.deepcopy(wl), until=36000)
        cluster.check_kv_conservation()
    assert res["switch"]["completed"] == res["static"]["completed"] == len(wl)
    assert res["switch"]["requests_per_s"] >= \
        0.99 * res["static"]["requests_per_s"]
    assert res["switch"]["ttft_p95_s"] < 0.8 * res["static"]["ttft_p95_s"], \
        (res["switch"]["ttft_p95_s"], res["static"]["ttft_p95_s"])
    assert res["switch"]["policy"]["role_flips"] >= 2
    assert res["static"]["policy"]["role_flips"] == 0


@pytest.mark.slow
@pytest.mark.timing
def test_role_switch_no_worse_than_static_6p2d_threaded():
    """Same comparison under the threaded drive (real daemon dispatch
    threads on a scaled wall clock).  Real scheduling jitter inflates the
    STATIC baseline nonlinearly on busy machines (backlog compounds) while
    role switching self-corrects, so the deterministic 'strictly lower
    p95' bound lives in the stepped test above; here we pin throughput >=
    static and p95 within a bounded band, with thresholds scaled by
    FLEX_TIMING_SLACK and one retry to ride out contention spikes."""
    from repro.configs import get_config
    slack = timing_slack()
    wl = _bursty()

    def run_pair():
        res = {}
        for name, deploy in [("static", deployment_6p2d()),
                             ("switch", deployment_role_switch(
                                 ttft_hi_s=0.5, ttft_lo_s=0.2,
                                 cooldown_s=2.0))]:
            cluster = Cluster(get_config("mixtral-8x7b"), deploy,
                              sim_cfg=SimConfig(prefill_window=4),
                              drive="threaded", time_scale=0.1)
            res[name] = cluster.run(copy.deepcopy(wl), until=3000)
            cluster.check_kv_conservation()
        assert res["switch"]["completed"] \
            == res["static"]["completed"] == len(wl)
        assert res["switch"]["policy"]["role_flips"] >= 2
        return (res["switch"]["requests_per_s"]
                / res["static"]["requests_per_s"],
                res["switch"]["ttft_p95_s"] / res["static"]["ttft_p95_s"])

    rps_lo, p95_hi = 0.85 / slack, max(1.25, slack)
    for attempt in range(2):
        rps_ratio, p95_ratio = run_pair()
        if rps_ratio > rps_lo and p95_ratio < p95_hi:
            break
    assert rps_ratio > rps_lo, (rps_ratio, slack)
    assert p95_ratio < p95_hi, (p95_ratio, slack)


def test_op_duration_unified_across_drives():
    """One duration implementation for both drives: slow_factor applies,
    the straggler EWMA updates, decode late-binds its batch, and
    bookkeeping ops are never slowed (the DMA engine isn't a straggler)."""
    from repro.configs import get_config
    from repro.core.api import OpDescriptor, OpType
    from repro.serving import deployment_dynamic
    cluster = Cluster(get_config("mixtral-8x7b"), deployment_dynamic())
    inst = cluster.instances[0]
    inst.slow_factor = 3.0
    op = OpDescriptor(OpType.LAUNCH, phase=Phase.PREFILL,
                      meta={"est_duration": 1.0})
    assert inst.op_duration(op) == pytest.approx(3.0)
    assert inst.ewma_step > 0
    other = OpDescriptor(OpType.RECORD_EVENT, meta={"est_duration": 1.0})
    assert inst.op_duration(other) == pytest.approx(1.0)   # not slowed
    # decode late-binds: duration computed from the CURRENT batch, not the
    # estimate frozen into the op at enqueue
    inst.slow_factor = 1.0
    dec = OpDescriptor(OpType.LAUNCH, phase=Phase.DECODE,
                       meta={"est_duration": 1e-9})
    solo = inst.op_duration(dec)
    from repro.serving.request import Request
    inst.active = [Request(prompt_len=4096, max_new_tokens=1)
                   for _ in range(64)]
    assert inst.op_duration(dec) > solo
    assert dec.meta["tokens"] == 64                        # decode_meta bound


def test_switch_role_rejects_invalid_flips():
    from repro.configs import get_config
    cluster = Cluster(get_config("mixtral-8x7b"), deployment_6p2d(),
                      sim_cfg=SimConfig(prefill_window=4))
    d0 = next(i for i in cluster.instances if i.name == "D0")
    assert not cluster.switch_role(d0, "decode")      # already decode
    assert not cluster.switch_role(d0, "weights")     # unknown role
    assert cluster.switch_role("D0", "prefill")       # by name works
    assert d0 in cluster.prefill_pool and d0 not in cluster.decode_pool
    assert cluster.switch_role(d0, "decode")
    assert d0 in cluster.decode_pool
    # colocated instances have no switchable role
    from repro.serving import deployment_dynamic
    co = Cluster(get_config("mixtral-8x7b"), deployment_dynamic())
    assert not co.switch_role(co.instances[0], "prefill")


def test_policy_telemetry_in_run_results():
    """Cluster.run results carry control-plane telemetry (what the BENCH
    artifacts record): dispatch debug state, roles, flips, queue depths."""
    from repro.configs import get_config
    from repro.serving import deployment_dynamic, make_workload
    cluster = Cluster(get_config("mixtral-8x7b"), deployment_dynamic())
    res = cluster.run(make_workload(40, 512, 128, rate=100.0, seed=1),
                      until=36000)
    tele = res["policy"]
    assert tele["cluster_policy"] == "LeastLoadedPolicy"
    assert tele["role_flips"] == 0
    assert set(tele["roles"]) == {"C0", "C1", "C2"}
    # dynamic_pd instances expose realized decode share
    assert any("decode_share_realized" in st
               for st in tele["dispatch"].values())
    for depths in tele["queue_depths"].values():
        assert {"prefill_ops", "decode_ops", "waiting", "active"} <= \
            set(depths)


@pytest.mark.parametrize("drive", drive_modes())
def test_cluster_session_leak_free(drive):
    """Both drives release their session cleanly (threaded stops daemon
    threads in run(); stepped sessions close idempotently)."""
    from repro.configs import get_config
    from repro.serving import make_workload
    cluster = Cluster(get_config("mixtral-8x7b"), deployment_6p2d(),
                      sim_cfg=SimConfig(prefill_window=4), drive=drive,
                      time_scale=0.05)
    res = cluster.run(make_workload(20, 256, 32, rate=200.0, seed=2),
                      until=3000)
    assert res["completed"] == 20
    cluster.close()
    assert all(d.closed for d in cluster.session.daemons)
