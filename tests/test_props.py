"""Property-based tests (hypothesis) for system invariants."""
import math

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; skipping property tests")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.api import OpDescriptor, OpType, Phase
from repro.core.profiler import Profiler
from repro.sched import (DynamicPDPolicy, PolicyContext,
                         StaticTimeSlicePolicy)
from repro.serving.kvcache import OutOfPages, PagedAllocator
from repro.training.optimizer import AdamWConfig, lr_at


# ------------------------------------------------------------ allocator
@settings(max_examples=200, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from(["alloc", "append", "free"]),
              st.integers(0, 15), st.integers(1, 70)),
    min_size=1, max_size=60))
def test_paged_allocator_invariants(ops):
    a = PagedAllocator(num_pages=32, page_size=8)
    live = set()
    for kind, rid, tokens in ops:
        try:
            if kind == "alloc" and rid not in live:
                a.allocate(rid, tokens)
                live.add(rid)
            elif kind == "append" and rid in live:
                a.append(rid, tokens)
            elif kind == "free":
                a.free(rid)
                live.discard(rid)
        except OutOfPages:
            pass
        a.check_invariants()
    for rid in list(live):
        a.free(rid)
    a.check_invariants()
    assert a.free_pages == 32


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 2000), st.integers(1, 64))
def test_pages_needed_exact(tokens, page_size):
    a = PagedAllocator(4096, page_size)
    a.allocate(1, tokens)
    pages = a.page_table(1)
    assert (len(pages) - 1) * page_size < tokens <= len(pages) * page_size


# ------------------------------------------------------------ scheduler
@settings(max_examples=60, deadline=None)
@given(st.floats(0.05, 0.95), st.lists(st.booleans(), min_size=20,
                                       max_size=100))
def test_deficit_rr_share_convergence(share, arrivals):
    """With both queues always backlogged, realized device-time share
    converges to the target regardless of op interleaving."""
    from collections import deque
    pol = StaticTimeSlicePolicy(share)
    prof = Profiler()
    queues = {Phase.PREFILL: deque(), Phase.DECODE: deque(),
              Phase.OTHER: deque()}

    def refill():
        for q, ph in ((queues[Phase.PREFILL], Phase.PREFILL),
                      (queues[Phase.DECODE], Phase.DECODE)):
            while len(q) < 3:
                q.append(OpDescriptor(OpType.LAUNCH, phase=ph))

    durations = {Phase.PREFILL: 0.010, Phase.DECODE: 0.004}
    now = 0.0
    for _ in range(400):
        refill()
        ph = pol.select(PolicyContext(queues=queues, prof=prof, now=now))
        op = queues[ph].popleft()
        pol.on_dispatch(op, durations[ph])
        now += durations[ph]
    total = sum(pol._spent.values())
    realized = pol._spent[Phase.DECODE] / total
    assert abs(realized - share) < 0.08


@settings(max_examples=40, deadline=None)
@given(st.floats(0.05, 0.95))
def test_scheduler_work_conserving(share):
    """An empty opposite queue must never block dispatch."""
    from collections import deque
    pol = StaticTimeSlicePolicy(share)
    prof = Profiler()
    queues = {Phase.PREFILL: deque(), Phase.DECODE: deque(),
              Phase.OTHER: deque()}
    queues[Phase.DECODE].append(OpDescriptor(OpType.LAUNCH,
                                             phase=Phase.DECODE))
    assert pol.select(PolicyContext(queues=queues, prof=prof)) == Phase.DECODE
    queues[Phase.DECODE].clear()
    queues[Phase.PREFILL].append(OpDescriptor(OpType.LAUNCH,
                                              phase=Phase.PREFILL))
    assert pol.select(PolicyContext(queues=queues, prof=prof)) == Phase.PREFILL


def test_dynamic_ttft_guard_prevents_starvation():
    """A prefill older than the guard always dispatches next."""
    from collections import deque
    from repro.sched import DynamicPDConfig
    pol = DynamicPDPolicy(DynamicPDConfig(ttft_guard_s=0.5), decode_share=0.95)
    prof = Profiler()
    old_prefill = OpDescriptor(OpType.LAUNCH, phase=Phase.PREFILL)
    old_prefill.enqueue_time = 0.0
    queues = {Phase.PREFILL: deque([old_prefill]),
              Phase.DECODE: deque([OpDescriptor(OpType.LAUNCH,
                                                phase=Phase.DECODE)]),
              Phase.OTHER: deque()}
    assert pol.select(
        PolicyContext(queues=queues, prof=prof, now=1.0)) == Phase.PREFILL


# ------------------------------------------------------------ lr schedule
@settings(max_examples=60, deadline=None)
@given(st.integers(1, 500), st.integers(501, 5000))
def test_lr_schedule_properties(warmup, total):
    cfg = AdamWConfig(lr=1e-3, warmup_steps=warmup, total_steps=total,
                      min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert abs(float(lr_at(cfg, warmup)) - 1e-3) < 1e-9
    assert float(lr_at(cfg, total)) >= 0.1 * 1e-3 - 1e-12
    # monotone decay after warmup
    a = float(lr_at(cfg, warmup + (total - warmup) // 3))
    b = float(lr_at(cfg, warmup + 2 * (total - warmup) // 3))
    assert a >= b


# ------------------------------------------------------------ moe routing
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(2, 8))
def test_moe_dropless_capacity(s, e):
    """Dropless inference capacity can never drop a token."""
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models.moe import _route_chunk, moe_params
    import dataclasses as dc
    cfg = get_config("mixtral-8x7b").reduced()
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, num_experts=e,
                                         top_k=min(2, e)))
    p = moe_params(cfg, jax.random.PRNGKey(0))
    from repro.distributed.sharding import unbox
    p = unbox(p)
    x = jax.random.normal(jax.random.PRNGKey(s), (1, s, cfg.d_model),
                          jnp.float32)
    y, aux = _route_chunk(cfg, p, x, dropless=True)
    # every token got its full top-k gate mass => nonzero output
    assert bool(jnp.all(jnp.any(jnp.abs(y) > 0, axis=-1)))
