"""Registry + published-hyperparameter sanity checks."""
import pytest

from repro.configs import (SHAPES, get_config, list_archs, all_cells,
                           shape_applicable)
from repro.configs.base import Family


def test_ten_archs_registered():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch,lo,hi", [
    ("nemotron-4-340b", 330e9, 350e9),
    ("starcoder2-3b", 2.7e9, 3.3e9),
    ("olmo-1b", 1.0e9, 1.4e9),
    ("gemma2-2b", 2.3e9, 3.0e9),
    ("mamba2-780m", 0.7e9, 0.9e9),
    ("grok-1-314b", 300e9, 330e9),
    ("mixtral-8x7b", 44e9, 48e9),
    ("qwen2-vl-2b", 1.3e9, 1.8e9),
    ("jamba-1.5-large-398b", 380e9, 410e9),
    ("seamless-m4t-medium", 0.4e9, 0.9e9),
])
def test_param_counts_match_published(arch, lo, hi):
    n = get_config(arch).param_count()
    assert lo <= n <= hi, f"{arch}: {n / 1e9:.1f}B outside [{lo}, {hi}]"


def test_moe_active_params():
    grok = get_config("grok-1-314b")
    assert grok.active_param_count() < 0.35 * grok.param_count()
    mix = get_config("mixtral-8x7b")
    assert 12e9 < mix.active_param_count() < 14e9  # ~12.9B active


def test_exact_assigned_dims():
    c = get_config("nemotron-4-340b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (96, 18432, 96, 8, 73728, 256000)
    c = get_config("jamba-1.5-large-398b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (72, 8192, 64, 8, 24576, 65536)
    assert c.moe.num_experts == 16 and c.moe.top_k == 2
    assert c.attn_every == 8  # 1:7 attention:mamba
    c = get_config("mamba2-780m")
    assert c.ssm.state_dim == 128 and c.num_heads == 0


def test_cell_skip_rules():
    cells = all_cells()
    assert len(cells) == 40
    runnable = [(a, s) for a, s, ok, _ in cells if ok]
    skipped = [(a, s) for a, s, ok, _ in cells if not ok]
    assert len(runnable) == 33
    # long_500k runs only for sub-quadratic / bounded-cache families
    assert ("mamba2-780m", "long_500k") in runnable
    assert ("jamba-1.5-large-398b", "long_500k") in runnable
    assert ("gemma2-2b", "long_500k") in runnable
    assert all(s == "long_500k" for _, s in skipped)
    assert ("nemotron-4-340b", "long_500k") in skipped


def test_reduced_configs_small():
    for arch in list_archs():
        r = get_config(arch).reduced()
        assert r.d_model <= 128 and r.vocab_size <= 256
        assert r.param_count() < 30e6
