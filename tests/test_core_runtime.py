"""FlexNPU core: daemon, client, handle virtualization, policies, profiler."""
import threading
import time

import pytest

from repro.core import (DynamicPDConfig, DynamicPDPolicy, FIFOPolicy,
                        FlexClient, FlexDaemon, OpDescriptor, OpType,
                        PassthroughClient, Phase, Profiler, RealBackend,
                        StaticTimeSlicePolicy)


def make_daemon(policy=None):
    d = FlexDaemon(0, RealBackend(), policy or FIFOPolicy())
    d.start()
    return d


def test_transparency_same_results_both_clients():
    """The engine-visible contract: identical results under passthrough and
    FlexNPU interposition (the paper's transparency property)."""
    work = lambda x: x * x + 1
    d = make_daemon()
    flex = FlexClient(d)
    passthrough = PassthroughClient()
    s = flex.create_stream(phase=Phase.DECODE)
    a = [flex.launch(s, work, i, phase=Phase.DECODE).result()
         for i in range(20)]
    b = [passthrough.launch(0, work, i).result() for i in range(20)]
    assert a == b
    d.stop()
    passthrough.close()


def test_handle_virtualization():
    d = make_daemon()
    c = FlexClient(d)
    s1 = c.create_stream(phase=Phase.PREFILL)
    s2 = c.create_stream(phase=Phase.DECODE)
    assert s1 != s2
    h1 = c.malloc(1 << 20, tag="kv")
    h2 = c.malloc(1 << 10, tag="scratch")
    assert h1 != h2
    assert d.allocated_bytes == (1 << 20) + (1 << 10)
    c.free(h1)
    assert d.allocated_bytes == (1 << 10)
    assert d.peak_bytes == (1 << 20) + (1 << 10)
    d.stop()


def test_async_launch_returns_before_completion():
    d = make_daemon()
    c = FlexClient(d)
    ev = threading.Event()
    fut = c.launch(0, lambda: (ev.wait(1.0), 42)[1], phase=Phase.PREFILL)
    assert not fut.done()       # async proxying: control returned immediately
    ev.set()
    assert fut.result(2.0) == 42
    d.stop()


def test_failed_device_errors_futures():
    d = make_daemon()
    c = FlexClient(d)
    d.stop()
    d.fail()
    fut = c.launch(0, lambda: 1, phase=Phase.DECODE)
    with pytest.raises(RuntimeError):
        fut.result(1.0)


def test_profiler_phase_stats():
    d = make_daemon()
    c = FlexClient(d)
    for i in range(10):
        c.launch(0, lambda: time.sleep(0.002), phase=Phase.DECODE,
                 meta={"tokens": 4, "bytes": 1e9, "flops": 1e9}).result()
    st = d.profiler.stats[Phase.DECODE]
    assert st.ops_completed == 10
    assert st.tokens_done == 40
    assert st.ewma_exec > 0.001
    assert 0.0 < st.bandwidth_util() <= 1.0
    d.stop()


def _run_policy_mix(policy, n=60, exec_s=0.001):
    """Feed interleaved prefill/decode ops; returns realized decode share."""
    d = FlexDaemon(0, RealBackend(), policy)
    c = FlexClient(d)
    futs = []
    for i in range(n):
        phase = Phase.DECODE if i % 2 else Phase.PREFILL
        futs.append(c.launch(0, lambda: time.sleep(exec_s), phase=phase,
                             meta={"est_duration": exec_s}))
    d.start()          # start AFTER enqueue so both queues are contended
    for f in futs:
        f.result(30.0)
    d.stop()
    spent = policy._spent
    total = sum(spent.values())
    return spent[Phase.DECODE] / total


@pytest.mark.parametrize("share", [0.05, 0.5, 0.95])
def test_static_timeslice_work_conserving_completion(share):
    """Even at extreme shares every op completes (work conservation): when
    the favored queue drains, the other phase gets the device.  Share
    convergence itself is tested deterministically in test_props.py."""
    realized = _run_policy_mix(StaticTimeSlicePolicy(share))
    assert 0.0 < realized < 1.0


def test_dynamic_policy_bounds():
    pol = DynamicPDPolicy(DynamicPDConfig(min_share=0.1, max_share=0.9))
    _run_policy_mix(pol)
    assert 0.1 <= pol.decode_share <= 0.9


def test_fifo_is_arrival_ordered():
    d = FlexDaemon(0, RealBackend(), FIFOPolicy())
    c = FlexClient(d)
    order = []
    futs = []
    for i in range(12):
        phase = Phase.DECODE if i % 3 else Phase.PREFILL
        futs.append(c.launch(0, lambda i=i: order.append(i), phase=phase))
    d.start()
    for f in futs:
        f.result(10.0)
    d.stop()
    assert order == sorted(order)
