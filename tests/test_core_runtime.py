"""FlexNPU core: daemon, client, handle virtualization, policies, profiler."""
import threading
import time

import pytest

from repro.core import (DynamicPDConfig, DynamicPDPolicy, FIFOPolicy,
                        FlexClient, FlexDaemon, OpDescriptor, OpType,
                        PassthroughClient, Phase, Profiler, RealBackend,
                        StaticTimeSlicePolicy)


def make_daemon(policy=None):
    d = FlexDaemon(0, RealBackend(), policy or FIFOPolicy())
    d.start()
    return d


def test_transparency_same_results_both_clients():
    """The engine-visible contract: identical results under passthrough and
    FlexNPU interposition (the paper's transparency property)."""
    work = lambda x: x * x + 1
    d = make_daemon()
    flex = FlexClient(d)
    passthrough = PassthroughClient()
    s = flex.create_stream(phase=Phase.DECODE)
    a = [flex.launch(s, work, i, phase=Phase.DECODE).result()
         for i in range(20)]
    b = [passthrough.launch(0, work, i).result() for i in range(20)]
    assert a == b
    d.stop()
    passthrough.close()


def test_handle_virtualization():
    d = make_daemon()
    c = FlexClient(d)
    s1 = c.create_stream(phase=Phase.PREFILL)
    s2 = c.create_stream(phase=Phase.DECODE)
    assert s1 != s2
    h1 = c.malloc(1 << 20, tag="kv")
    h2 = c.malloc(1 << 10, tag="scratch")
    assert h1 != h2
    assert d.allocated_bytes == (1 << 20) + (1 << 10)
    c.free(h1)
    assert d.allocated_bytes == (1 << 10)
    assert d.peak_bytes == (1 << 20) + (1 << 10)
    d.stop()


def test_async_launch_returns_before_completion():
    d = make_daemon()
    c = FlexClient(d)
    ev = threading.Event()
    fut = c.launch(0, lambda: (ev.wait(1.0), 42)[1], phase=Phase.PREFILL)
    assert not fut.done()       # async proxying: control returned immediately
    ev.set()
    assert fut.result(2.0) == 42
    d.stop()


def test_failed_device_errors_futures():
    d = make_daemon()
    c = FlexClient(d)
    d.stop()
    d.fail()
    fut = c.launch(0, lambda: 1, phase=Phase.DECODE)
    with pytest.raises(RuntimeError):
        fut.result(1.0)


def test_profiler_phase_stats():
    d = make_daemon()
    c = FlexClient(d)
    for i in range(10):
        c.launch(0, lambda: time.sleep(0.002), phase=Phase.DECODE,
                 meta={"tokens": 4, "bytes": 1e9, "flops": 1e9}).result()
    st = d.profiler.stats[Phase.DECODE]
    assert st.ops_completed == 10
    assert st.tokens_done == 40
    assert st.ewma_exec > 0.001
    assert 0.0 < st.bandwidth_util() <= 1.0
    d.stop()


def _run_policy_mix(policy, n=60, exec_s=0.001):
    """Feed interleaved prefill/decode ops; returns realized decode share."""
    d = FlexDaemon(0, RealBackend(), policy)
    c = FlexClient(d)
    futs = []
    for i in range(n):
        phase = Phase.DECODE if i % 2 else Phase.PREFILL
        futs.append(c.launch(0, lambda: time.sleep(exec_s), phase=phase,
                             meta={"est_duration": exec_s}))
    d.start()          # start AFTER enqueue so both queues are contended
    for f in futs:
        f.result(30.0)
    d.stop()
    spent = policy._spent
    total = sum(spent.values())
    return spent[Phase.DECODE] / total


@pytest.mark.parametrize("share", [0.05, 0.5, 0.95])
def test_static_timeslice_work_conserving_completion(share):
    """Even at extreme shares every op completes (work conservation): when
    the favored queue drains, the other phase gets the device.  Share
    convergence itself is tested deterministically in test_props.py."""
    realized = _run_policy_mix(StaticTimeSlicePolicy(share))
    assert 0.0 < realized < 1.0


def test_dynamic_policy_bounds():
    pol = DynamicPDPolicy(DynamicPDConfig(min_share=0.1, max_share=0.9))
    _run_policy_mix(pol)
    assert 0.1 <= pol.decode_share <= 0.9


def test_fifo_is_arrival_ordered():
    d = FlexDaemon(0, RealBackend(), FIFOPolicy())
    c = FlexClient(d)
    order = []
    futs = []
    for i in range(12):
        phase = Phase.DECODE if i % 3 else Phase.PREFILL
        futs.append(c.launch(0, lambda i=i: order.append(i), phase=phase))
    d.start()
    for f in futs:
        f.result(10.0)
    d.stop()
    assert order == sorted(order)


# ---------------------------------------------------------------- v2 verbs
def test_destroy_stream_and_event_end_to_end():
    d = make_daemon()
    c = FlexClient(d)
    s = c.create_stream(phase=Phase.PREFILL)
    ev = c.create_event()
    assert len(d.streams) == 1 and len(d.events) == 1
    c.launch(s, lambda: 1, phase=Phase.PREFILL).result(5)
    c.destroy_event(ev)
    c.destroy_stream(s)
    assert len(d.streams) == 0 and len(d.events) == 0
    # destroyed handles are gone: re-destroying a stream with pending work
    s2 = c.create_stream()
    gate = threading.Event()
    fut = c.launch(s2, lambda: gate.wait(5))
    with pytest.raises(RuntimeError):
        c.destroy_stream(s2)       # stream busy: refuse, don't corrupt
    gate.set()
    fut.result(5)
    c.synchronize(s2)
    c.destroy_stream(s2)
    assert len(d.streams) == 0
    d.stop()


def test_destroy_event_with_pending_record_refused():
    d = FlexDaemon(0, RealBackend())      # not started: record stays queued
    c = FlexClient(d)
    ev = c.create_event()
    c.record_event(ev, 0)
    with pytest.raises(RuntimeError):
        c.destroy_event(ev)
    d.start()
    d.drain()
    c.destroy_event(ev)
    assert len(d.events) == 0
    d.stop()


def test_passthrough_synchronize_waits_for_inflight_op():
    """Regression: q.empty() is true while the worker still executes the
    dequeued op — synchronize must track in-flight state."""
    c = PassthroughClient()
    done = []
    c.launch(0, lambda: (time.sleep(0.25), done.append(1)))
    c.synchronize(0)               # honors the vstream argument too
    assert done == [1]
    c.close()


class _TickBackend:
    """Minimal stepped backend for driving a daemon by hand."""

    def __init__(self):
        self.t = 0.0

    def now(self):
        return self.t

    def estimate(self, op):
        return float(op.meta.get("est_duration", 1e-3))


def test_flex_synchronize_marker_is_stream_scoped():
    """The SYNCHRONIZE marker completes once ITS stream drains — work still
    queued on a sibling stream does not gate it (stepped drive, so dispatch
    order is fully deterministic under a decode-biased policy)."""
    from repro.core.api import OpDescriptor, OpType
    d = FlexDaemon(0, _TickBackend(), StaticTimeSlicePolicy(0.99))
    c = FlexClient(d)
    s1 = c.create_stream(phase=Phase.PREFILL)
    s2 = c.create_stream(phase=Phase.DECODE)
    slow = c.launch(s1, None, phase=Phase.PREFILL,
                    meta={"est_duration": 100.0})
    fast = c.launch(s2, None, phase=Phase.DECODE,
                    meta={"est_duration": 0.001})
    marker = OpDescriptor(OpType.SYNCHRONIZE, vstream=s2)
    d.enqueue(marker)
    op = d.select_next(0.0)            # decode bias: fast, not slow
    assert op.future is fast
    d.mark_complete(op, 0.001)
    op = d.select_next(0.002)          # marker now heads s2; OTHER preempts
    assert op.op == OpType.SYNCHRONIZE
    d.mark_complete(op, 0.002)
    assert marker.future.done() and fast.done()
    assert not slow.done() and d.pending_count() == 1  # s1 never gated s2


# -------------------------------------------------------------- fault paths
def test_fail_without_sink_errors_queued_futures():
    d = FlexDaemon(0, RealBackend())      # stepped: ops stay queued
    c = FlexClient(d)
    futs = [c.launch(0, lambda: 1, phase=Phase.DECODE) for _ in range(4)]
    d.fail()
    for f in futs:
        with pytest.raises(RuntimeError):
            f.result(1.0)
    assert d.pending_count() == 0


def test_fail_with_requeue_sink_hands_ops_over():
    d = FlexDaemon(0, RealBackend())
    c = FlexClient(d)
    futs = [c.launch(0, lambda: 1, phase=Phase.PREFILL) for _ in range(3)]
    salvaged = []
    d.fail(requeue_sink=salvaged.append)
    assert len(salvaged) == 3
    assert all(not f.done() for f in futs)  # sink owns them now, not errored
    assert d.pending_count() == 0


def test_enqueue_after_fail_errors_immediately():
    d = FlexDaemon(0, RealBackend())
    c = FlexClient(d)
    d.fail()
    fut = c.launch(0, lambda: 1, phase=Phase.DECODE)
    with pytest.raises(RuntimeError):
        fut.result(0.1)
    with pytest.raises(RuntimeError):
        c.malloc(64)


def test_fail_clears_ordering_state():
    d = FlexDaemon(0, RealBackend())
    c = FlexClient(d)
    ev = c.create_event()
    c.launch(0, lambda: 1, phase=Phase.PREFILL)
    c.record_event(ev, 0)
    d.fail(requeue_sink=lambda op: None)
    assert not d._stream_pending and not d._event_state
    assert d.select_next(0.0) is None     # failed daemon dispatches nothing
