"""Traffic engine (v5): arrivals, specs, tenants, closed loops, shedding.

Three layers of coverage:
  * unit — arrival/length samplers, the make_traffic registry, Zipf mixes,
    SloAwareAdmission ordering/fairness/shedding in isolation;
  * regression — seed determinism, v4 RNG byte-compatibility of
    make_workload, the serving.workload one-release shim, ValueError on
    unknown arrival names (the old code silently fell back to uniform);
  * end-to-end — shedding honesty (completed + rejected + failed ==
    generated, shed requests REJECTED never silently dropped) and
    closed-loop conservation (in-flight never exceeds the user
    population), each in BOTH daemon drive modes.
"""
import copy

import numpy as np
import pytest

from conftest import drive_modes
from repro.configs import get_config
from repro.sched import SloAwareAdmission, make_policy
from repro.serving import (SLO, Cluster, DeploymentSpec, Request,
                           RequestState, SimConfig)
from repro.traffic import (DEFAULT_CLASSES, ClosedLoopPool, PromptClass,
                           TrafficSpec, default_tiers, list_traffic,
                           make_arrivals, make_lengths, make_traffic,
                           make_workload, traffic_is_closed_loop, zipf_probs)

CFG = get_config("qwen2-vl-2b")


# ---------------------------------------------------------------- samplers

def test_poisson_arrivals_sorted_and_rate():
    rng = np.random.default_rng(0)
    t = make_arrivals("poisson", rng, 4000, rate=50.0)
    assert len(t) == 4000 and np.all(np.diff(t) >= 0) and t[0] >= 0
    # mean inter-arrival ~ 1/rate
    assert 4000 / t[-1] == pytest.approx(50.0, rel=0.1)


def test_uniform_arrivals_draw_nothing():
    rng = np.random.default_rng(7)
    state = rng.bit_generator.state
    t = make_arrivals("uniform", rng, 100, rate=10.0)
    assert np.allclose(np.diff(t), 0.1)
    # v4 byte-compat: the uniform schedule consumes NO rng draws
    assert rng.bit_generator.state == state


def test_gamma_arrivals_burstier_than_poisson():
    rng = np.random.default_rng(0)
    pois = np.diff(make_arrivals("poisson", rng, 8000, rate=20.0))
    gam = np.diff(make_arrivals("gamma", np.random.default_rng(0), 8000,
                                rate=20.0, cv=3.0))
    assert np.std(gam) / np.mean(gam) > 2.0 * np.std(pois) / np.mean(pois)
    assert np.mean(gam) == pytest.approx(1 / 20.0, rel=0.15)


def test_mmpp_burst_phase_runs_faster():
    rng = np.random.default_rng(1)
    t = make_arrivals("mmpp", rng, 6000, rate=20.0,
                      phases=((5.0, 1.0), (5.0, 10.0)))
    cycle = t % 10.0
    base = np.sum(cycle < 5.0)
    burst = np.sum(cycle >= 5.0)
    assert burst > 5 * base          # 10x phase carries ~10x the arrivals


def test_mmpp_rejects_degenerate_phases():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        make_arrivals("mmpp", rng, 10, rate=5.0, phases=())
    with pytest.raises(ValueError):
        make_arrivals("mmpp", rng, 10, rate=5.0,
                      phases=((1.0, 0.0), (2.0, 0.0)))


def test_unknown_arrival_name_raises():
    """Regression: pre-v5 make_workload silently fell back to the uniform
    schedule on a typo'd arrival name."""
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="poissonn"):
        make_arrivals("poissonn", rng, 10, rate=5.0)
    with pytest.raises(ValueError, match="unknown arrival"):
        make_workload(10, 128, 64, rate=5.0, arrival="bursty")
    with pytest.raises(ValueError):
        make_arrivals("poisson", rng, 10, rate=0.0)


def test_lengths_lognormal_and_fixed():
    rng = np.random.default_rng(0)
    state = rng.bit_generator.state
    fixed = make_lengths("lognormal", rng, 500, mean=256, cv=0.0)
    assert np.all(fixed == 256)
    # cv<=0 short-circuits to the fixed sampler with ZERO rng draws
    assert rng.bit_generator.state == state
    ln = make_lengths("lognormal", rng, 20000, mean=256, cv=0.5)
    assert np.mean(ln) == pytest.approx(256, rel=0.05) and np.min(ln) >= 1
    assert np.issubdtype(ln.dtype, np.integer)


def test_lengths_pareto_heavy_tail():
    rng = np.random.default_rng(0)
    p = make_lengths("pareto", rng, 40000, mean=512, alpha=2.5)
    assert np.mean(p) == pytest.approx(512, rel=0.1)
    # heavy tail: max far beyond a lognormal's reach at this cv
    assert np.max(p) > 4 * 512 and np.min(p) >= 1


def test_lengths_empirical_histogram():
    rng = np.random.default_rng(0)
    e = make_lengths("empirical", rng, 9000, mean=0,
                     hist=((128, 1.0), (1024, 3.0)))
    assert set(np.unique(e)) == {128, 1024}
    assert np.mean(e == 1024) == pytest.approx(0.75, abs=0.03)
    with pytest.raises(ValueError):
        make_lengths("weibull", rng, 10, mean=100)


def test_zipf_probs_skew():
    p = zipf_probs(6, 1.1)
    assert p.sum() == pytest.approx(1.0) and np.all(np.diff(p) < 0)
    assert p[0] > 3 * p[-1]
    flat = zipf_probs(6, 0.0)
    assert np.allclose(flat, 1 / 6)


# ------------------------------------------------------ spec + determinism

def _req_key(r):
    return (round(r.arrival_time, 12), r.prompt_len, r.max_new_tokens,
            r.tenant, None if r.slo is None else r.slo.priority)


def test_spec_seed_determinism():
    spec = TrafficSpec(n=200, rate=30.0, arrival="gamma",
                       arrival_knobs={"cv": 2.0},
                       tenants=default_tiers())
    a = [_req_key(r) for r in spec.generate(5)]
    b = [_req_key(r) for r in spec.generate(5)]
    c = [_req_key(r) for r in spec.generate(6)]
    assert a == b
    assert a != c


def test_spec_tenant_shares_and_slos():
    tiers = default_tiers()
    spec = TrafficSpec(n=3000, rate=100.0, tenants=tiers)
    reqs = spec.generate(0)
    counts = {t.name: 0 for t in tiers}
    for r in reqs:
        counts[r.tenant] += 1
        assert r.slo is not None and r.slo.priority >= 0
    for t in tiers:
        assert counts[t.name] / len(reqs) == pytest.approx(t.share, abs=0.05)
    # interactive outranks standard outranks batch
    by = {t.name: t.slo for t in tiers}
    assert by["interactive"].priority > by["standard"].priority \
        > by["batch"].priority
    assert by["interactive"].ttft_s < by["standard"].ttft_s


def test_spec_zipf_class_mix():
    spec = TrafficSpec(n=4000, rate=100.0, zipf_alpha=2.0)
    reqs = spec.generate(3)
    head = DEFAULT_CLASSES[0]
    frac = np.mean([r.prompt_len > 0 and _class_of(r) == head.name
                    for r in reqs])
    assert frac > 0.5            # alpha=2 concentrates mass on the head


def _class_of(r):
    # classes are distinguishable by their (mean) length configuration via
    # the tenant-free chat class at 256/128; use prompt stats as a proxy
    for c in DEFAULT_CLASSES:
        if abs(np.log(max(r.prompt_len, 1) / c.input_len)) < 0.7 \
                and abs(np.log(max(r.max_new_tokens, 1) / c.output_len)) < 0.7:
            return c.name
    return "?"


def test_registry_make_traffic():
    names = list_traffic()
    for want in ("open_loop", "tiered", "tiered_burst", "closed_loop",
                 "bursty_phase_shift", "deepseek_1k1k"):
        assert want in names
    wl = make_traffic("tiered", n=50, rate=20.0, seed=1)
    assert len(wl) == 50 and all(isinstance(r, Request) for r in wl)
    assert any(r.tenant for r in wl)
    assert not traffic_is_closed_loop("tiered")
    assert traffic_is_closed_loop("closed_loop")
    pool = make_traffic("closed_loop", users=4, requests_per_user=2, seed=1)
    assert isinstance(pool, ClosedLoopPool)
    with pytest.raises(KeyError, match="open_loop"):
        make_traffic("no_such_traffic")
    with pytest.raises(TypeError):
        make_traffic("tiered", bogus_knob=3)


def test_workload_shim_removed():
    """The one-release ``repro.serving.workload`` shim is GONE (v6): the
    module neither imports nor exists on disk, and no src/ module still
    references the deleted path (grep-test, so a reintroduced import
    fails CI)."""
    import pathlib

    with pytest.raises(ImportError):
        import repro.serving.workload  # noqa: F401
    import repro.serving as serving
    root = pathlib.Path(serving.__file__).parents[1]   # src/repro
    assert not (root / "serving" / "workload.py").exists()
    offenders = []
    for py in root.rglob("*.py"):
        for line in py.read_text().splitlines():
            ls = line.strip()
            if ls.startswith(("import ", "from ")) \
                    and "serving.workload" in ls:
                offenders.append(f"{py}: {ls}")
    assert not offenders, f"modules importing the deleted shim: {offenders}"
    # the package-level re-exports stay public API and must be the SAME
    # objects repro.traffic.workloads defines
    import repro.traffic.workloads as traffic
    for name in ("make_workload", "bursty_phase_shift", "deepseek_1k1k",
                 "deepseek_1k4k", "qwen_grid"):
        assert getattr(serving, name) is getattr(traffic, name), name


def test_make_workload_v4_rng_byte_compat():
    """The migrated make_workload must reproduce v4's request stream
    bit-for-bit: arrivals drawn first (exponential), then input lognormal,
    then output lognormal, all on one default_rng(seed)."""
    wl = make_workload(64, 512, 256, rate=50.0, seed=9, length_cv=0.3)
    rng = np.random.default_rng(9)
    gaps = rng.exponential(1.0 / 50.0, size=64)
    arrivals = np.cumsum(gaps)
    sigma = np.sqrt(np.log(1 + 0.3 ** 2))
    mu_in = np.log(512) - sigma ** 2 / 2
    ins = np.maximum(1, rng.lognormal(mu_in, sigma, size=64).astype(int))
    mu_out = np.log(256) - sigma ** 2 / 2
    outs = np.maximum(1, rng.lognormal(mu_out, sigma, size=64).astype(int))
    assert [r.arrival_time for r in wl] == pytest.approx(arrivals.tolist())
    assert [r.prompt_len for r in wl] == ins.tolist()
    assert [r.max_new_tokens for r in wl] == outs.tolist()


# ----------------------------------------------------- admission (units)

def _req(tenant, prio, weight, arrival=0.0, ttft=1.0):
    return Request(prompt_len=128, max_new_tokens=16, arrival_time=arrival,
                   tenant=tenant,
                   slo=SLO(ttft_s=ttft, tpot_s=1.0, priority=prio,
                           weight=weight))


def test_slo_admission_strict_priority():
    pol = make_policy("slo_aware")
    assert isinstance(pol, SloAwareAdmission)
    waiting = [_req("batch", 0, 1.0), _req("standard", 1, 2.0),
               _req("interactive", 2, 4.0), _req("interactive", 2, 4.0)]
    i = pol.pick_next(waiting)
    assert waiting[i].tenant == "interactive"
    # within a tenant the order is FIFO: first interactive wins
    assert i == 2


def test_slo_admission_stride_fairness():
    """Two tenants at the same priority with weights 4:1 admit ~4:1."""
    pol = SloAwareAdmission()
    admitted = {"a": 0, "b": 0}
    waiting = [_req("a", 1, 4.0) for _ in range(80)] \
        + [_req("b", 1, 1.0) for _ in range(80)]
    for _ in range(50):
        i = pol.pick_next(waiting)
        req = waiting.pop(i)
        pol.on_admit(req)
        admitted[req.tenant] += 1
    assert admitted["a"] == pytest.approx(40, abs=3)
    assert admitted["b"] >= 5       # weighted share, not starvation


def test_slo_admission_sheds_doomed_low_priority():
    pol = SloAwareAdmission(shed_wait_factor=2.0, shed_below_priority=2)
    doomed = _req("batch", 0, 1.0, arrival=0.0, ttft=1.0)
    fresh = _req("batch", 0, 1.0, arrival=9.5, ttft=1.0)
    protected = _req("interactive", 2, 4.0, arrival=0.0, ttft=1.0)
    shed = pol.shed([doomed, fresh, protected], now=10.0)
    assert doomed in shed            # 10s old >> 2 x 1s TTFT SLO
    assert fresh not in shed         # still inside its window
    assert protected not in shed     # priority >= shed_below_priority
    assert pol.shed_requests == len(shed)


def test_slo_admission_max_queue_depth_overflow():
    pol = SloAwareAdmission(max_queue_depth=3)
    waiting = [_req("batch", 0, 1.0, arrival=float(i)) for i in range(5)] \
        + [_req("interactive", 2, 4.0, arrival=5.0)]
    shed = pol.shed(waiting, now=5.0)
    assert len(shed) == len(waiting) - 3
    # overflow shedding takes the lowest-priority, oldest requests first
    assert all(r.priority == 0 for r in shed)
    assert {r.arrival_time for r in shed} == {0.0, 1.0, 2.0}


# ------------------------------------------------- end-to-end (both drives)

def _tiered_cluster(drive, admission_knobs=None):
    deploy = DeploymentSpec(mode="dynamic_pd", colocated_instances=1,
                            colocated_chips=2,
                            admission_policy="slo_aware",
                            admission_knobs=admission_knobs or {})
    return Cluster(CFG, deploy,
                   sim_cfg=SimConfig(max_num_seqs=32, prefill_window=2),
                   drive=drive, time_scale=0.1)


@pytest.mark.parametrize("drive", drive_modes())
def test_shedding_honesty_conservation(drive):
    """Every generated request ends in exactly one terminal bucket and the
    run()-level telemetry agrees with the per-request states."""
    spec = TrafficSpec(n=120, rate=60.0, arrival="mmpp",
                       arrival_knobs={"phases": ((0.5, 1.0), (2.0, 10.0))},
                       classes=(PromptClass("rag", 2048, 32),
                                PromptClass("chat", 256, 32)),
                       tenants=default_tiers(ttft_scale=0.25))
    wl = spec.generate(2)
    cluster = _tiered_cluster(drive, {"max_queue_depth": 8,
                                      "shed_wait_factor": 1.0})
    res = cluster.run(copy.deepcopy(wl), until=36000)
    assert res["generated"] == 120
    assert res["completed"] + res["rejected"] + res["failed"] == 120
    states = [r.state for r in cluster.requests]
    assert all(s in (RequestState.DONE, RequestState.REJECTED,
                     RequestState.FAILED) for s in states)
    assert sum(s == RequestState.REJECTED for s in states) == res["rejected"]
    assert res["rejected"] > 0       # the tight queue bound actually shed
    assert res["shed_requests"] == res["rejected"]
    # rejected requests carry a finish_time (they terminated, not vanished)
    assert all(r.finish_time >= 0 for r in cluster.requests
               if r.state == RequestState.REJECTED)
    # telemetry surfaces the admission layer
    adm = res["policy"]["admission"]
    assert sum(v["rejected"] for v in adm.values()) == res["rejected"]
    # per-tier breakdown exists and covers every tier seen
    assert set(res["tenants"]) == {r.tenant for r in cluster.requests}
    for tier in res["tenants"].values():
        for key in ("ttft_p99_s", "tpot_p99_s", "slo_attainment",
                    "ttft_attainment", "generated"):
            assert key in tier
    if drive == "stepped":
        cluster.check_kv_conservation()


@pytest.mark.parametrize("drive", drive_modes())
def test_closed_loop_conservation(drive):
    """Closed-loop pool: in-flight never exceeds the user population, every
    issued request completes, and the pool drains the full budget."""
    pool = make_traffic("closed_loop", users=6, requests_per_user=3,
                        think_time_s=0.05, seed=4,
                        spec=TrafficSpec(classes=(PromptClass("chat", 128,
                                                              32),),
                                         tenants=default_tiers()))
    deploy = DeploymentSpec(mode="dynamic_pd", colocated_instances=1,
                            colocated_chips=2)
    cluster = Cluster(CFG, deploy, sim_cfg=SimConfig(max_num_seqs=32),
                      drive=drive, time_scale=0.1)
    res = cluster.run(traffic=pool, until=36000)
    assert res["generated"] == 6 * 3
    assert res["completed"] == 6 * 3
    assert res["rejected"] == 0 and res["failed"] == 0
    assert pool.exhausted() and pool.in_flight == 0
    assert pool.peak_in_flight <= 6
    assert all(r.state == RequestState.DONE for r in cluster.requests)
    # think times put gaps between a user's consecutive requests
    by_user = {}
    for r in pool.generated:
        by_user.setdefault(pool.user_of(r), []).append(r)
    assert len(by_user) == 6
    for reqs in by_user.values():
        assert len(reqs) == 3
        reqs.sort(key=lambda r: r.arrival_time)
        for a, b in zip(reqs, reqs[1:]):
            assert b.arrival_time >= a.finish_time
    if drive == "stepped":
        cluster.check_kv_conservation()


def test_closed_loop_pool_unit():
    pool = ClosedLoopPool(TrafficSpec(n=1, rate=1.0), users=3,
                          requests_per_user=2, think_time_s=0.1, seed=0)
    first = pool.initial()
    assert len(first) == 3 and pool.in_flight == 3
    assert not pool.exhausted()
    nxt = pool.on_complete(first[0], now=1.0)
    assert nxt is not None and nxt.arrival_time >= 1.0
    assert pool.in_flight == 3       # one retired, one issued
    # unknown request (not ours) is ignored
    assert pool.on_complete(Request(prompt_len=8, max_new_tokens=1),
                            now=2.0) is None
    # completing a request twice doesn't double-issue
    assert pool.on_complete(first[0], now=2.0) is None
    # first[1]/first[2] each trigger their user's second (and last) request
    tail = [pool.on_complete(first[1], now=3.0),
            pool.on_complete(first[2], now=3.0)]
    assert all(t is not None for t in tail)
    # budgets now spent: retiring the second-round requests issues nothing
    for r in [nxt, *tail]:
        assert pool.on_complete(r, now=4.0) is None
    assert pool.exhausted() and pool.in_flight == 0
    assert pool.peak_in_flight <= 3
    assert len(pool.generated) == 6
    assert sorted(pool.user_of(r) for r in pool.generated) == [0, 0, 1, 1,
                                                               2, 2]


def test_tenant_blind_requests_still_summarize():
    """Requests without tenants keep the pre-v5 summary shape: no tenants
    key materializes out of thin air."""
    from repro.serving import summarize
    wl = make_workload(10, 64, 16, rate=100.0, seed=0)
    for i, r in enumerate(wl):
        r.state = RequestState.DONE
        r.prefill_start = r.arrival_time
        r.first_token_time = r.arrival_time + 0.1
        r.second_token_time = r.arrival_time + 0.2
        r.last_token_time = r.arrival_time + 0.2
        r.generated = 2
        r.finish_time = r.arrival_time + 0.2
    s = summarize(wl)
    assert s["completed"] == 10 and s["rejected"] == 0
    assert "tenants" not in s or s["tenants"] == {}
