"""CI gate over BENCH_*.json artifacts: fail on dishonest telemetry.

The bench-smoke job runs every benchmark at a tiny scale and uploads the
JSON artifacts; this validator then FAILS the job if any artifact is
malformed or carries dishonest numbers — the checks are structural, so a
benchmark that silently stops emitting a metric (or starts emitting NaN)
breaks CI instead of quietly degrading the perf trajectory.

Checks (per row):
  * ``name`` present, ``us_per_call`` a finite number;
  * every ``slo_attainment`` / ``ttft_attainment`` mapping — wherever it
    appears — is non-empty with finite values in [0, 1] (a NaN attainment
    means a tier had zero terminal requests: the run was too small or the
    accounting lost requests);
  * rows that carry request accounting satisfy conservation:
    ``completed + rejected (+ failed) == generated`` — shed requests must
    be counted, never silently dropped;
  * rows flagged ``conserved`` actually say true;
  * simulator-throughput rows (PR 9): ``events_per_s`` must be present,
    finite, and > 0 wherever a row carries it, and must not fall more
    than 30% below the row's recorded ``floor_events_per_s`` — a raw
    sim-speed regression fails CI instead of silently eating every
    downstream sweep's wall-clock budget;
  * prefix-reuse telemetry (v6) is honest wherever it appears:
    ``hit_rate`` finite in [0, 1], ``flops_saved`` and
    ``remote_fetch_bytes`` finite and >= 0 — and a row that claims reuse
    (``hit_rate`` > 0) must carry ``flops_saved`` > 0 (a hit that saved
    nothing means the admission path stopped charging the cost model);
  * prediction telemetry (v9) is honest wherever a row carries a
    ``prediction`` section: every MAPE (online + fit-time, latency +
    length) finite in [0, 5]; the length model observed exactly the
    COMPLETED requests (``length.n == completed`` — a gap means the
    serving loop stopped feeding the sketches, or fed them rejects);
    and every predictive_sched tiered_burst predictive row records a
    ``meets_acceptance`` verdict (the acceptance bar may not silently
    disappear from the artifact).

    python -m benchmarks.validate_artifacts bench-out/BENCH_*.json
"""
from __future__ import annotations

import json
import math
import sys


def _finite(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x)


def check_row(row: dict, where: str) -> list:
    errors = []
    if not row.get("name"):
        errors.append(f"{where}: row missing name")
    if not _finite(row.get("us_per_call")):
        errors.append(f"{where}: us_per_call missing or non-finite")
    d = row.get("derived")
    if not isinstance(d, dict):
        return errors
    for key in ("slo_attainment", "ttft_attainment"):
        if key not in d:
            continue
        att = d[key]
        if not isinstance(att, dict) or not att:
            errors.append(f"{where}: {key} empty or not a mapping")
            continue
        for tier, v in att.items():
            if not _finite(v) or not 0.0 <= v <= 1.0:
                errors.append(f"{where}: {key}[{tier}] = {v!r} "
                              "(must be finite in [0, 1])")
    if "generated" in d and "completed" in d:
        total = d.get("completed", 0) + d.get("rejected", 0) \
            + d.get("failed", 0)
        if total != d["generated"]:
            errors.append(
                f"{where}: conservation broken — completed+rejected+failed"
                f" = {total} != generated = {d['generated']}")
    if d.get("conserved") is False:
        errors.append(f"{where}: row self-reports conserved=false")
    if "events_per_s" in d:
        ev = d["events_per_s"]
        if not _finite(ev) or ev <= 0:
            errors.append(f"{where}: events_per_s = {ev!r} "
                          "(must be finite and > 0)")
        else:
            floor = d.get("floor_events_per_s")
            if not _finite(floor):
                errors.append(f"{where}: events_per_s without a finite "
                              f"floor_events_per_s ({floor!r})")
            elif floor > 0 and ev < 0.7 * floor:
                errors.append(
                    f"{where}: events_per_s = {ev} regressed >30% below "
                    f"the recorded floor {floor} — simulator hot path "
                    "got slower")
    if "hit_rate" in d:
        hr = d["hit_rate"]
        if not _finite(hr) or not 0.0 <= hr <= 1.0:
            errors.append(f"{where}: hit_rate = {hr!r} "
                          "(must be finite in [0, 1])")
        for key in ("flops_saved", "remote_fetch_bytes"):
            if key in d and (not _finite(d[key]) or d[key] < 0):
                errors.append(f"{where}: {key} = {d[key]!r} "
                              "(must be finite and >= 0)")
        if _finite(hr) and hr > 0 and not d.get("flops_saved", 0) > 0:
            errors.append(f"{where}: hit_rate {hr} > 0 but flops_saved "
                          f"= {d.get('flops_saved')!r} — reuse claimed "
                          "without recompute savings")
    if isinstance(d.get("prediction"), dict):
        errors.extend(_check_prediction(d, where))
    if ".tiered_burst.predictive" in str(row.get("name", "")) \
            and "meets_acceptance" not in d:
        errors.append(f"{where}: predictive tiered_burst row without a "
                      "meets_acceptance verdict")
    return errors


def _check_prediction(d: dict, where: str) -> list:
    """Honesty checks for the v9 ``prediction`` telemetry section."""
    errors = []
    pred = d["prediction"]

    def mape_ok(stats, label):
        m = stats.get("mape")
        if not _finite(m) or not 0.0 <= m <= 5.0:
            errors.append(f"{where}: {label} mape = {m!r} "
                          "(must be finite in [0, 5])")

    lat = pred.get("latency")
    if isinstance(lat, dict):
        if lat.get("n", 0) > 0:
            mape_ok(lat, "latency online")
        for phase, cal in (lat.get("fit") or {}).items():
            mape_ok(cal, f"latency fit[{phase}]")
    lng = pred.get("length")
    if isinstance(lng, dict):
        if lng.get("n", 0) > 0:
            mape_ok(lng, "length online")
        # the serving loop observes one length per COMPLETED request —
        # nothing more (rejects carry no realized length), nothing less
        if "completed" in d and lng.get("n", -1) != d["completed"]:
            errors.append(
                f"{where}: length.n = {lng.get('n')!r} != completed = "
                f"{d['completed']} — length observations out of step "
                "with completions")
    return errors


def check_file(path: str) -> list:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        return [f"{path}: no rows"]
    errors = []
    for row in rows:
        errors.extend(check_row(row, f"{path}:{row.get('name', '?')}"))
    return errors


def main(argv=None) -> int:
    paths = list(argv if argv is not None else sys.argv[1:])
    if not paths:
        print("usage: python -m benchmarks.validate_artifacts "
              "BENCH_*.json", file=sys.stderr)
        return 2
    errors = []
    for path in paths:
        errors.extend(check_file(path))
    for e in errors:
        print(f"FAIL {e}", file=sys.stderr)
    print(f"validated {len(paths)} artifact(s): "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
