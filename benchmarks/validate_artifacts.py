"""CI gate over BENCH_*.json artifacts: fail on dishonest telemetry.

The bench-smoke job runs every benchmark at a tiny scale and uploads the
JSON artifacts; this validator then FAILS the job if any artifact is
malformed or carries dishonest numbers — the checks are structural, so a
benchmark that silently stops emitting a metric (or starts emitting NaN)
breaks CI instead of quietly degrading the perf trajectory.

Checks (per row):
  * ``name`` present, ``us_per_call`` a finite number;
  * every ``slo_attainment`` / ``ttft_attainment`` mapping — wherever it
    appears — is non-empty with finite values in [0, 1] (a NaN attainment
    means a tier had zero terminal requests: the run was too small or the
    accounting lost requests);
  * rows that carry request accounting satisfy conservation:
    ``completed + rejected (+ failed) == generated`` — shed requests must
    be counted, never silently dropped;
  * rows flagged ``conserved`` actually say true;
  * simulator-throughput rows (PR 9): ``events_per_s`` must be present,
    finite, and > 0 wherever a row carries it, and must not fall more
    than 30% below the row's recorded ``floor_events_per_s`` — a raw
    sim-speed regression fails CI instead of silently eating every
    downstream sweep's wall-clock budget;
  * prefix-reuse telemetry (v6) is honest wherever it appears:
    ``hit_rate`` finite in [0, 1], ``flops_saved`` and
    ``remote_fetch_bytes`` finite and >= 0 — and a row that claims reuse
    (``hit_rate`` > 0) must carry ``flops_saved`` > 0 (a hit that saved
    nothing means the admission path stopped charging the cost model).

    python -m benchmarks.validate_artifacts bench-out/BENCH_*.json
"""
from __future__ import annotations

import json
import math
import sys


def _finite(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x)


def check_row(row: dict, where: str) -> list:
    errors = []
    if not row.get("name"):
        errors.append(f"{where}: row missing name")
    if not _finite(row.get("us_per_call")):
        errors.append(f"{where}: us_per_call missing or non-finite")
    d = row.get("derived")
    if not isinstance(d, dict):
        return errors
    for key in ("slo_attainment", "ttft_attainment"):
        if key not in d:
            continue
        att = d[key]
        if not isinstance(att, dict) or not att:
            errors.append(f"{where}: {key} empty or not a mapping")
            continue
        for tier, v in att.items():
            if not _finite(v) or not 0.0 <= v <= 1.0:
                errors.append(f"{where}: {key}[{tier}] = {v!r} "
                              "(must be finite in [0, 1])")
    if "generated" in d and "completed" in d:
        total = d.get("completed", 0) + d.get("rejected", 0) \
            + d.get("failed", 0)
        if total != d["generated"]:
            errors.append(
                f"{where}: conservation broken — completed+rejected+failed"
                f" = {total} != generated = {d['generated']}")
    if d.get("conserved") is False:
        errors.append(f"{where}: row self-reports conserved=false")
    if "events_per_s" in d:
        ev = d["events_per_s"]
        if not _finite(ev) or ev <= 0:
            errors.append(f"{where}: events_per_s = {ev!r} "
                          "(must be finite and > 0)")
        else:
            floor = d.get("floor_events_per_s")
            if not _finite(floor):
                errors.append(f"{where}: events_per_s without a finite "
                              f"floor_events_per_s ({floor!r})")
            elif floor > 0 and ev < 0.7 * floor:
                errors.append(
                    f"{where}: events_per_s = {ev} regressed >30% below "
                    f"the recorded floor {floor} — simulator hot path "
                    "got slower")
    if "hit_rate" in d:
        hr = d["hit_rate"]
        if not _finite(hr) or not 0.0 <= hr <= 1.0:
            errors.append(f"{where}: hit_rate = {hr!r} "
                          "(must be finite in [0, 1])")
        for key in ("flops_saved", "remote_fetch_bytes"):
            if key in d and (not _finite(d[key]) or d[key] < 0):
                errors.append(f"{where}: {key} = {d[key]!r} "
                              "(must be finite and >= 0)")
        if _finite(hr) and hr > 0 and not d.get("flops_saved", 0) > 0:
            errors.append(f"{where}: hit_rate {hr} > 0 but flops_saved "
                          f"= {d.get('flops_saved')!r} — reuse claimed "
                          "without recompute savings")
    return errors


def check_file(path: str) -> list:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        return [f"{path}: no rows"]
    errors = []
    for row in rows:
        errors.extend(check_row(row, f"{path}:{row.get('name', '?')}"))
    return errors


def main(argv=None) -> int:
    paths = list(argv if argv is not None else sys.argv[1:])
    if not paths:
        print("usage: python -m benchmarks.validate_artifacts "
              "BENCH_*.json", file=sys.stderr)
        return 2
    errors = []
    for path in paths:
        errors.extend(check_file(path))
    for e in errors:
        print(f"FAIL {e}", file=sys.stderr)
    print(f"validated {len(paths)} artifact(s): "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
