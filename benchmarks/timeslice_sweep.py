"""Figures 5/6 — throughput & KV-memory usage vs time-slice ratio.

Fig 5 (1K-1K, matching prefill throughput): increasing the DECODE share
first raises system throughput ~linearly, then saturates.
Fig 6 (1K-4K, matching decode throughput): increasing the PREFILL share has
little effect once decode dominates.

Sustained near-capacity arrivals keep both phase queues contended so the
static ratio actually binds (work-conserving scheduling hides the knob under
bursty loads)."""
from __future__ import annotations

import copy


def _run_share(cfg, share, wl):
    from repro.serving import Cluster
    from repro.serving.simulator import DeploymentSpec
    deploy = DeploymentSpec(mode="static_slice", colocated_instances=1,
                            colocated_chips=128, decode_share=share)
    cl = Cluster(cfg, deploy)
    res = cl.run(copy.deepcopy(wl), until=72000)
    inst = cl.instances[0]
    peak_kv_frac = None
    if inst.kv_capacity:
        peak_kv_frac = min(1.0, max(inst.kv_used, 0) / inst.kv_capacity)
    # policy telemetry: how closely the realized device-time split tracked
    # the configured share (SchedulerPolicy.debug_state -> BENCH artifacts)
    share_realized = res["policy"]["dispatch"].get(inst.name, {}).get(
        "decode_share_realized")
    return res, peak_kv_frac, share_realized


def run(quick: bool = False):
    from repro.configs import get_config
    from repro.serving import make_workload

    # DeepSeek-R1-class 300B+ archs need the 910C's 64 GB/card to fit the
    # paper's 16-card prefill instances; on 16 GB v5e chips the largest
    # assigned MoE that fits this geometry is Mixtral (DESIGN.md §8).
    cfg = get_config("mixtral-8x7b")
    n = 200 if quick else 600
    rows = []
    # Figure 5: decode-share sweep, balanced workload, sustained arrivals
    wl5 = make_workload(n, 1024, 1024, rate=40.0, seed=8)
    for share in ([0.2, 0.5, 0.8] if quick else
                  [0.1, 0.25, 0.4, 0.55, 0.7, 0.85]):
        res, kv, realized = _run_share(cfg, share, wl5)
        rows.append((f"fig5.decode_share_{int(share * 100)}",
                     1e6 / max(res["requests_per_s"], 1e-9),
                     {"decode_share": share,
                      "decode_share_realized": realized,
                      "rps": round(res["requests_per_s"], 2),
                      "tokens_per_s": round(res["output_tokens_per_s"], 0),
                      "kv_used_frac": kv}))
    # Figure 6: prefill-share sweep (1 - decode share), decode-heavy
    wl6 = make_workload(max(n // 3, 80), 1024, 4096, rate=10.0, seed=9)
    for pshare in ([0.2, 0.5, 0.8] if quick else
                   [0.1, 0.25, 0.4, 0.55, 0.7]):
        res, kv, realized = _run_share(cfg, 1 - pshare, wl6)
        rows.append((f"fig6.prefill_share_{int(pshare * 100)}",
                     1e6 / max(res["requests_per_s"], 1e-9),
                     {"prefill_share": pshare,
                      "decode_share_realized": realized,
                      "rps": round(res["requests_per_s"], 2),
                      "tokens_per_s": round(res["output_tokens_per_s"], 0),
                      "kv_used_frac": kv}))
    return rows
