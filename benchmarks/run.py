"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = compact JSON of the
table-specific numbers, including the paper's reference values).
``--json-dir`` additionally writes one ``BENCH_<tag>.json`` per module —
the CI bench-smoke job uploads these as artifacts so the perf trajectory
is captured per PR.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table3,fig2]
                                            [--json-dir bench-out]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

MODULES = [
    ("table1", "benchmarks.virt_overhead"),
    ("table2", "benchmarks.pd_bottlenecks"),
    ("table3", "benchmarks.pd_disagg_vs_dynamic"),
    ("table4", "benchmarks.colocation_ttft"),
    ("fig2", "benchmarks.decode_bandwidth"),
    ("fig56", "benchmarks.timeslice_sweep"),
    ("role_switch", "benchmarks.role_switch"),
    ("slo_attainment", "benchmarks.slo_attainment"),
    ("prefix_reuse", "benchmarks.prefix_reuse"),
    ("kv_streaming", "benchmarks.kv_streaming"),
    ("microbatch_prefill", "benchmarks.microbatch_prefill"),
    ("roofline", "benchmarks.roofline"),
    ("kernels", "benchmarks.kernels_microbench"),
    ("sim_throughput", "benchmarks.sim_throughput"),
    ("predictive_sched", "benchmarks.predictive_sched"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json-dir", default="",
                    help="write BENCH_<tag>.json per module into this dir")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.json_dir:
        os.makedirs(args.json_dir, exist_ok=True)

    print("name,us_per_call,derived")
    failures = []
    for tag, modname in MODULES:
        if only and tag not in only:
            continue
        t0 = time.time()
        try:
            import importlib
            mod = importlib.import_module(modname)
            rows = mod.run(quick=args.quick)
        except Exception as e:  # keep the harness running
            failures.append((tag, repr(e)))
            print(f"{tag}.ERROR,0,{json.dumps(repr(e)[:120])}")
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{json.dumps(json.dumps(derived))}")
        elapsed = time.time() - t0
        print(f"# {tag} done in {elapsed:.1f}s", file=sys.stderr)
        if args.json_dir:
            from benchmarks._cli import rows_payload
            path = os.path.join(args.json_dir, f"BENCH_{tag}.json")
            with open(path, "w") as f:
                json.dump({"tag": tag, "module": modname,
                           "quick": args.quick,
                           "elapsed_s": round(elapsed, 2),
                           "rows": rows_payload(rows)}, f, indent=2)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
