"""Table 3 — DeepSeek-R1-scale throughput: static 6P2D PD disaggregation vs
FlexNPU dynamic PD co-location (3 x 128) on 384 chips.

The paper's workloads: 1K-1K (balanced; prefill-bottlenecked under 6P2D,
+26.33% for FlexNPU) and 1K-4K (decode-heavy, +5.15%).  DeepSeek-R1 itself is
not in the assigned pool; the largest assigned MoE archs stand in (geometry,
workloads and deployment match the paper).

``--sweep-link-bw`` sweeps the KV-transfer link bandwidth: disaggregation
moves every prompt's KV cache through the occupancy-aware LinkModel (copy
engine + per-link contention), so its throughput degrades as the link
shrinks — while dynamic co-location, which never moves KV, is unaffected.
Each disagg row also reports the realized transfer-queueing delay
(actual - contention-free transfer time).
"""
from __future__ import annotations

import copy

# default sweep: ICI-class fast link down to a constrained inter-host link
SWEEP_BWS = (400e9, 50e9, 10e9, 2e9)


def _run(cfg, deploy, wl, sim_cfg=None):
    from repro.serving import Cluster
    return Cluster(cfg, deploy, sim_cfg=sim_cfg).run(
        copy.deepcopy(wl), until=72000)


def run(quick: bool = False):
    from repro.configs import get_config
    from repro.serving import (deployment_6p2d, deployment_dynamic,
                               make_workload)

    # DeepSeek-R1-class 300B+ archs need the 910C's 64 GB/card to fit the
    # paper's 16-card prefill instances; on 16 GB v5e chips the largest
    # assigned MoE that fits this geometry is Mixtral (DESIGN.md §8).
    cfg = get_config("mixtral-8x7b")
    n1, n4 = (400, 150) if quick else (1500, 500)
    rows = []
    for wl_name, in_len, out_len, n, paper_gain in [
            ("1k1k", 1024, 1024, n1, 0.2633),
            ("1k4k", 1024, 4096, n4, 0.0515)]:
        wl = make_workload(n, in_len, out_len, rate=1e5, seed=3)  # saturate
        r_disagg = _run(cfg, deployment_6p2d(), wl)
        r_dyn = _run(cfg, deployment_dynamic(), wl)
        gain = r_dyn["requests_per_s"] / r_disagg["requests_per_s"] - 1
        rows.append((f"table3.{wl_name}.disagg_6p2d_rps",
                     1e6 / max(r_disagg["requests_per_s"], 1e-9),
                     {"rps": round(r_disagg["requests_per_s"], 2),
                      "tokens_per_s": round(
                          r_disagg["output_tokens_per_s"], 0)}))
        rows.append((f"table3.{wl_name}.dynamic_colocation_rps",
                     1e6 / max(r_dyn["requests_per_s"], 1e-9),
                     {"rps": round(r_dyn["requests_per_s"], 2),
                      "tokens_per_s": round(r_dyn["output_tokens_per_s"], 0),
                      "improvement": f"{gain:+.2%}",
                      "paper_improvement": f"{paper_gain:+.2%}"}))
    return rows


def sweep_link_bw(quick: bool = False, bws=SWEEP_BWS):
    """Disagg vs dynamic across KV-link bandwidths (1K-1K, saturating)."""
    from repro.configs import get_config
    from repro.serving import (SimConfig, deployment_6p2d, deployment_dynamic,
                               make_workload)

    cfg = get_config("mixtral-8x7b")
    n = 200 if quick else 800
    wl = make_workload(n, 1024, 1024, rate=1e5, seed=3)  # saturate
    rows = []
    for bw in bws:
        sim = SimConfig(transfer_bw=bw)
        r_disagg = _run(cfg, deployment_6p2d(), wl, sim_cfg=sim)
        r_dyn = _run(cfg, deployment_dynamic(), wl, sim_cfg=sim)
        tag = f"{bw / 1e9:g}GBps"
        rows.append((
            f"table3.link_sweep.{tag}.disagg",
            1e6 / max(r_disagg["requests_per_s"], 1e-9),
            {"link_bw_gbps": bw / 1e9,
             "rps": round(r_disagg["requests_per_s"], 2),
             "transfers": r_disagg.get("transfers", 0),
             "transfer_time_mean_ms": round(
                 r_disagg.get("transfer_time_mean_s", 0.0) * 1e3, 2),
             "transfer_queue_delay_mean_ms": round(
                 r_disagg.get("transfer_queue_delay_mean_s", 0.0) * 1e3, 2),
             "peak_link_concurrency": r_disagg.get(
                 "peak_link_concurrency", 0)}))
        rows.append((
            f"table3.link_sweep.{tag}.dynamic",
            1e6 / max(r_dyn["requests_per_s"], 1e-9),
            {"link_bw_gbps": bw / 1e9,
             "rps": round(r_dyn["requests_per_s"], 2),
             "transfers": r_dyn.get("transfers", 0)}))
    return rows


def main(argv=None) -> None:
    import argparse

    from benchmarks._cli import emit_rows

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--sweep-link-bw", action="store_true",
                    help="sweep KV-link bandwidth instead of Table 3")
    ap.add_argument("--json", default="",
                    help="also write the rows to this JSON file")
    args = ap.parse_args(argv)
    rows = sweep_link_bw(args.quick) if args.sweep_link_bw \
        else run(args.quick)
    emit_rows(rows, args.json)


if __name__ == "__main__":
    main()
