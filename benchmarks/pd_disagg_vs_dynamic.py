"""Table 3 — DeepSeek-R1-scale throughput: static 6P2D PD disaggregation vs
FlexNPU dynamic PD co-location (3 x 128) on 384 chips.

The paper's workloads: 1K-1K (balanced; prefill-bottlenecked under 6P2D,
+26.33% for FlexNPU) and 1K-4K (decode-heavy, +5.15%).  DeepSeek-R1 itself is
not in the assigned pool; the largest assigned MoE archs stand in (geometry,
workloads and deployment match the paper)."""
from __future__ import annotations

import copy


def _run(cfg, deploy, wl):
    from repro.serving import Cluster
    return Cluster(cfg, deploy).run(copy.deepcopy(wl), until=72000)


def run(quick: bool = False):
    from repro.configs import get_config
    from repro.serving import (deployment_6p2d, deployment_dynamic,
                               make_workload)

    # DeepSeek-R1-class 300B+ archs need the 910C's 64 GB/card to fit the
    # paper's 16-card prefill instances; on 16 GB v5e chips the largest
    # assigned MoE that fits this geometry is Mixtral (DESIGN.md §8).
    cfg = get_config("mixtral-8x7b")
    n1, n4 = (400, 150) if quick else (1500, 500)
    rows = []
    for wl_name, in_len, out_len, n, paper_gain in [
            ("1k1k", 1024, 1024, n1, 0.2633),
            ("1k4k", 1024, 4096, n4, 0.0515)]:
        wl = make_workload(n, in_len, out_len, rate=1e5, seed=3)  # saturate
        r_disagg = _run(cfg, deployment_6p2d(), wl)
        r_dyn = _run(cfg, deployment_dynamic(), wl)
        gain = r_dyn["requests_per_s"] / r_disagg["requests_per_s"] - 1
        rows.append((f"table3.{wl_name}.disagg_6p2d_rps",
                     1e6 / max(r_disagg["requests_per_s"], 1e-9),
                     {"rps": round(r_disagg["requests_per_s"], 2),
                      "tokens_per_s": round(
                          r_disagg["output_tokens_per_s"], 0)}))
        rows.append((f"table3.{wl_name}.dynamic_colocation_rps",
                     1e6 / max(r_dyn["requests_per_s"], 1e-9),
                     {"rps": round(r_dyn["requests_per_s"], 2),
                      "tokens_per_s": round(r_dyn["output_tokens_per_s"], 0),
                      "improvement": f"{gain:+.2%}",
                      "paper_improvement": f"{paper_gain:+.2%}"}))
    return rows
