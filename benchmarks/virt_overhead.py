"""Table 1 — performance impact of FlexNPU virtualization.

Real JAX execution (reduced model, CPU): identical serving workload under
  (a) native passthrough (direct submission, no interception), and
  (b) FlexNPU proxy (descriptors + handle translation + phase queues).
Reports total token throughput + relative performance, like the paper's
AISBench setup (which found 1.0108x — i.e. no overhead, slight win from
async proxying), plus the per-verb interposition latency of the v2 session
API (descriptor packaging + handle translation + queueing, no compute)."""
from __future__ import annotations

import time

import jax
import numpy as np


def _verb_latency(mode: str, n: int = 2000) -> float:
    """Mean per-op round-trip of an empty launch through a session."""
    from repro.core import Phase, connect
    with connect(mode=mode) as sess:
        stream = sess.create_stream(phase=Phase.OTHER)
        sess.launch(stream, lambda: None).result()  # warm the path
        t0 = time.perf_counter()
        for _ in range(n):
            sess.launch(stream, lambda: None)
        sess.synchronize(stream if mode != "passthrough" else None)
        dt = time.perf_counter() - t0
        sess.destroy_stream(stream)
    return dt / n


def run(quick: bool = False):
    from repro.configs import get_config
    from repro.distributed.sharding import unbox
    from repro.models import build_model
    from repro.serving.engine import RealEngine
    from repro.serving.request import Request

    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    n, out_len = (8, 8) if quick else (24, 16)
    rng = np.random.default_rng(0)

    def mk():
        return [Request(prompt_len=16, max_new_tokens=out_len,
                        prompt_tokens=np.random.default_rng(s).integers(
                            0, cfg.vocab_size, 16).tolist(),
                        arrival_time=s * 0.005)
                for s in range(n)]

    results = {}
    for mode in ("passthrough", "dynamic_pd"):
        # warmup compile outside the timed region
        eng = RealEngine(model, params, mode=mode, max_num_seqs=4,
                         max_len=16 + out_len + 8)
        try:
            res = eng.run(mk(), timeout=600)
        finally:
            eng.shutdown()
        results[mode] = res

    lat_pass = _verb_latency("passthrough")
    lat_flex = _verb_latency("flex")
    base = results["passthrough"]["output_tokens_per_s"]
    flex = results["dynamic_pd"]["output_tokens_per_s"]
    rows = [
        ("table1.verb_latency_us.passthrough", lat_pass * 1e6,
         {"per_op_us": round(lat_pass * 1e6, 2)}),
        ("table1.verb_latency_us.flex_proxy", lat_flex * 1e6,
         {"per_op_us": round(lat_flex * 1e6, 2),
          "overhead_us": round((lat_flex - lat_pass) * 1e6, 2)}),
        ("table1.native_passthrough.tokens_per_s", 1e6 / max(base, 1e-9),
         {"tokens_per_s": round(base, 2), "relative": 1.0}),
        ("table1.flexnpu_proxy.tokens_per_s", 1e6 / max(flex, 1e-9),
         {"tokens_per_s": round(flex, 2),
          "relative": round(flex / base, 4),
          "paper_relative": 1.0108}),
    ]
    return rows
