"""Table 4 — Qwen-class dense LLM: static PD co-location vs FlexNPU dynamic
PD co-location.  Paper setup: 4 I/O grids (256/256, 256/1024, 1024/256,
1024/1024), request_rate=4, max_num_seqs=4, 200 requests — an overload that
exposes static co-location's head-of-line blocking (TTFT in the hundreds of
seconds) while FlexNPU keeps TTFT sub-second at unchanged TPOT.

Qwen2.5-7B is not in the assigned pool; the assigned Qwen2-VL-2B backbone
(same family) stands in."""
from __future__ import annotations

import copy


def run(quick: bool = False):
    from repro.configs import get_config
    from repro.serving import Cluster, make_workload
    from repro.serving.simulator import DeploymentSpec, SimConfig

    cfg = get_config("qwen2-vl-2b")
    sim = SimConfig(max_num_seqs=4)
    n = 60 if quick else 200
    cells = [(256, 256), (256, 1024), (1024, 256), (1024, 1024)]
    paper = {  # static TTFT(ms), dynamic TTFT(ms), TTFT reduction
        (256, 256): (109941.5, 331.0, -0.9970),
        (256, 1024): (488099.0, 331.5, -0.9993),
        (1024, 256): (118164.5, 8568.5, -0.9275),
        (1024, 1024): (506536.5, 8311.5, -0.9836),
    }
    rows = []
    for i, o in cells:
        wl = make_workload(n, i, o, rate=4.0, seed=42)
        r = {}
        for mode in ("static_colocate", "dynamic_pd"):
            deploy = DeploymentSpec(mode=mode, colocated_instances=1,
                                    colocated_chips=4)
            r[mode] = Cluster(cfg, deploy, sim_cfg=sim).run(
                copy.deepcopy(wl), until=1e7)
        st, dy = r["static_colocate"], r["dynamic_pd"]
        ttft_red = dy["ttft_mean_s"] / st["ttft_mean_s"] - 1
        tpot_red = dy["tpot_mean_s"] / st["tpot_mean_s"] - 1
        tp_gain = dy["output_tokens_per_s"] / st["output_tokens_per_s"] - 1
        rows.append((
            f"table4.{i}_{o}.static", 1e6 / max(st["output_tokens_per_s"], 1e-9),
            {"tokens_per_s": round(st["output_tokens_per_s"], 2),
             "ttft_ms": round(st["ttft_mean_s"] * 1e3, 1),
             "tpot_ms": round(st["tpot_mean_s"] * 1e3, 3)}))
        rows.append((
            f"table4.{i}_{o}.flexnpu", 1e6 / max(dy["output_tokens_per_s"], 1e-9),
            {"tokens_per_s": round(dy["output_tokens_per_s"], 2),
             "ttft_ms": round(dy["ttft_mean_s"] * 1e3, 1),
             "tpot_ms": round(dy["tpot_mean_s"] * 1e3, 3),
             "ttft_reduction": f"{ttft_red:+.2%}",
             "tpot_change": f"{tpot_red:+.2%}",
             "throughput_change": f"{tp_gain:+.2%}",
             "paper_ttft_reduction": f"{paper[(i, o)][2]:+.2%}"}))
    return rows
