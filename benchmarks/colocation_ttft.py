"""Table 4 — Qwen-class dense LLM: static PD co-location vs FlexNPU dynamic
PD co-location.  Paper setup: 4 I/O grids (256/256, 256/1024, 1024/256,
1024/1024), request_rate=4, max_num_seqs=4, 200 requests — an overload that
exposes static co-location's head-of-line blocking (TTFT in the hundreds of
seconds) while FlexNPU keeps TTFT sub-second at unchanged TPOT.

Qwen2.5-7B is not in the assigned pool; the assigned Qwen2-VL-2B backbone
(same family) stands in.

``--sweep-link-bw`` adds the third deployment the paper argues against —
small-scale PD *disaggregation* — across KV-link bandwidths: every prompt's
KV cache crosses the occupancy-aware link, so TPOT inflates with transfer
queueing while both co-location modes are link-independent."""
from __future__ import annotations

import copy


def run(quick: bool = False):
    from repro.configs import get_config
    from repro.serving import Cluster, make_workload
    from repro.serving.simulator import DeploymentSpec, SimConfig

    cfg = get_config("qwen2-vl-2b")
    sim = SimConfig(max_num_seqs=4)
    n = 60 if quick else 200
    cells = [(256, 256), (256, 1024), (1024, 256), (1024, 1024)]
    paper = {  # static TTFT(ms), dynamic TTFT(ms), TTFT reduction
        (256, 256): (109941.5, 331.0, -0.9970),
        (256, 1024): (488099.0, 331.5, -0.9993),
        (1024, 256): (118164.5, 8568.5, -0.9275),
        (1024, 1024): (506536.5, 8311.5, -0.9836),
    }
    rows = []
    for i, o in cells:
        wl = make_workload(n, i, o, rate=4.0, seed=42)
        r = {}
        for mode in ("static_colocate", "dynamic_pd"):
            deploy = DeploymentSpec(mode=mode, colocated_instances=1,
                                    colocated_chips=4)
            r[mode] = Cluster(cfg, deploy, sim_cfg=sim).run(
                copy.deepcopy(wl), until=1e7)
        st, dy = r["static_colocate"], r["dynamic_pd"]
        ttft_red = dy["ttft_mean_s"] / st["ttft_mean_s"] - 1
        tpot_red = dy["tpot_mean_s"] / st["tpot_mean_s"] - 1
        tp_gain = dy["output_tokens_per_s"] / st["output_tokens_per_s"] - 1
        rows.append((
            f"table4.{i}_{o}.static", 1e6 / max(st["output_tokens_per_s"], 1e-9),
            {"tokens_per_s": round(st["output_tokens_per_s"], 2),
             "ttft_ms": round(st["ttft_mean_s"] * 1e3, 1),
             "tpot_ms": round(st["tpot_mean_s"] * 1e3, 3)}))
        dy_disp = next(iter(dy["policy"]["dispatch"].values()), {})
        rows.append((
            f"table4.{i}_{o}.flexnpu", 1e6 / max(dy["output_tokens_per_s"], 1e-9),
            {"tokens_per_s": round(dy["output_tokens_per_s"], 2),
             "ttft_ms": round(dy["ttft_mean_s"] * 1e3, 1),
             "tpot_ms": round(dy["tpot_mean_s"] * 1e3, 3),
             "ttft_reduction": f"{ttft_red:+.2%}",
             "tpot_change": f"{tpot_red:+.2%}",
             "throughput_change": f"{tp_gain:+.2%}",
             "paper_ttft_reduction": f"{paper[(i, o)][2]:+.2%}",
             # policy telemetry: where the dynamic policy's share settled
             "decode_share_target": dy_disp.get("decode_share_target"),
             "decode_share_realized": dy_disp.get("decode_share_realized")}))
    return rows


def sweep_link_bw(quick: bool = False, bws=(50e9, 2e9, 0.5e9, 0.25e9)):
    """Table-4-scale disaggregation under shrinking KV-link bandwidth,
    against the (link-independent) dynamic co-location reference.  Two
    single-chip prefill instances feed one decode instance, so bursts put
    concurrent transfers on the decode ingress link (occupancy)."""
    from repro.configs import get_config
    from repro.serving import Cluster, make_workload
    from repro.serving.simulator import DeploymentSpec, SimConfig

    cfg = get_config("qwen2-vl-2b")
    n = 60 if quick else 200
    wl = make_workload(n, 1024, 1024, rate=8.0, seed=42)
    dyn = Cluster(cfg, DeploymentSpec(mode="dynamic_pd",
                                      colocated_instances=1,
                                      colocated_chips=4),
                  sim_cfg=SimConfig(max_num_seqs=4)).run(
        copy.deepcopy(wl), until=1e7)
    rows = [("table4.link_sweep.dynamic_reference",
             1e6 / max(dyn["output_tokens_per_s"], 1e-9),
             {"tokens_per_s": round(dyn["output_tokens_per_s"], 2),
              "ttft_ms": round(dyn["ttft_mean_s"] * 1e3, 1),
              "tpot_ms": round(dyn["tpot_mean_s"] * 1e3, 3),
              "transfers": dyn.get("transfers", 0)})]
    deploy = DeploymentSpec(mode="disagg", prefill_instances=2,
                            prefill_chips=1, decode_instances=1,
                            decode_chips=2)
    for bw in bws:
        sim = SimConfig(max_num_seqs=4, transfer_bw=bw)
        r = Cluster(cfg, deploy, sim_cfg=sim).run(copy.deepcopy(wl),
                                                  until=1e7)
        rows.append((
            f"table4.link_sweep.{bw / 1e9:g}GBps.disagg",
            1e6 / max(r["output_tokens_per_s"], 1e-9),
            {"link_bw_gbps": bw / 1e9,
             "tokens_per_s": round(r["output_tokens_per_s"], 2),
             "ttft_ms": round(r["ttft_mean_s"] * 1e3, 1),
             "tpot_ms": round(r["tpot_mean_s"] * 1e3, 3),
             "transfers": r.get("transfers", 0),
             "transfer_queue_delay_mean_ms": round(
                 r.get("transfer_queue_delay_mean_s", 0.0) * 1e3, 2)}))
    return rows


def main(argv=None) -> None:
    import argparse

    from benchmarks._cli import emit_rows

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--sweep-link-bw", action="store_true")
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)
    rows = sweep_link_bw(args.quick) if args.sweep_link_bw \
        else run(args.quick)
    emit_rows(rows, args.json)


if __name__ == "__main__":
    main()
