"""Table 2 — phase-level bottlenecks under the static 6P2D split.

For each workload distribution, measures the pools' standalone peaks
(6 x 16-chip prefill pool, 2 x 144-chip decode pool) and the end-to-end
6P2D total, showing which phase caps the system (paper: 1K-1K is capped by
prefill at 490 RPS while decode could do 812; 1K-4K is capped by decode)."""
from __future__ import annotations

import copy


def _pool_peak(cfg, instances, chips, role, wl):
    """Saturating throughput of a standalone single-phase pool."""
    from repro.serving import Cluster
    from repro.serving.simulator import DeploymentSpec
    if role == "prefill":
        # prefill-only: count first tokens per second at saturation
        deploy = DeploymentSpec(mode="disagg", prefill_instances=instances,
                                prefill_chips=chips, decode_instances=1,
                                decode_chips=1024)  # decode never the limit
        cl = Cluster(cfg, deploy)
        res = cl.run(copy.deepcopy(wl), until=72000)
        done = [r for r in cl.requests if r.first_token_time >= 0]
        if not done:
            return 0.0
        t0 = min(r.arrival_time for r in done)
        t1 = max(r.first_token_time for r in done)
        return len(done) / max(t1 - t0, 1e-9)
    deploy = DeploymentSpec(mode="disagg", prefill_instances=12,
                            prefill_chips=64,  # oversized prefill feed
                            decode_instances=instances, decode_chips=chips)
    cl = Cluster(cfg, deploy)
    res = cl.run(copy.deepcopy(wl), until=72000)
    return res.get("requests_per_s", 0.0)


def run(quick: bool = False):
    from repro.configs import get_config
    from repro.serving import Cluster, deployment_6p2d, make_workload

    # DeepSeek-R1-class 300B+ archs need the 910C's 64 GB/card to fit the
    # paper's 16-card prefill instances; on 16 GB v5e chips the largest
    # assigned MoE that fits this geometry is Mixtral (DESIGN.md §8).
    cfg = get_config("mixtral-8x7b")
    n = 300 if quick else 1000
    rows = []
    for wl_name, in_len, out_len in [("1k1k", 1024, 1024),
                                     ("1k4k", 1024, 4096)]:
        nn = n if out_len == 1024 else max(n // 3, 150)
        wl = make_workload(nn, in_len, out_len, rate=1e5, seed=5)
        p_peak = _pool_peak(cfg, 6, 16, "prefill", wl)
        d_peak = _pool_peak(cfg, 2, 144, "decode", wl)
        total = Cluster(cfg, deployment_6p2d()).run(
            copy.deepcopy(wl), until=72000)["requests_per_s"]
        bottleneck = "prefill" if p_peak < d_peak else "decode"
        rows.append((f"table2.{wl_name}", 1e6 / max(total, 1e-9), {
            "total_rps": round(total, 1),
            "prefill_pool_peak_rps": round(p_peak, 1),
            "decode_pool_peak_rps": round(d_peak, 1),
            "bottleneck": bottleneck,
            "paper_bottleneck": "prefill" if wl_name == "1k1k" else "decode",
        }))
    return rows
