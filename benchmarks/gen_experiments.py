"""Regenerates the §Roofline table and §Perf log inside EXPERIMENTS.md from
results/dryrun/*.json and results/perf/*.json."""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
PEAK = 197e12


def fmt(x):
    return f"{x:.2e}"


def lb_step(rf):
    return max(rf["t_compute_s"], rf.get("t_memory_lb_s", 0.0),
               rf["t_collective_s"])


def mfu_lb(rf):
    s = lb_step(rf)
    return (rf["model_flops_per_device"] / PEAK) / s if s else 0.0


def roofline_section() -> str:
    recs = []
    for f in sorted(glob.glob(os.path.join(ROOT, "results/dryrun/*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    singles = [r for r in recs if r["mesh"] == "single_pod_16x16"]
    multis = {(r["arch"], r["shape"]): r for r in recs
              if r["mesh"] != "single_pod_16x16"}
    out = ["## §Roofline — per (arch x shape), single-pod 16x16 (256 chips)",
           "",
           "All terms seconds/step/device; `memory` column is lower-bound "
           "(ideal fusion)..upper-bound (XLA:CPU buffer granularity); "
           "`frac` = roofline fraction = (MODEL_FLOPS/peak) / dominant term "
           "(lower-bound basis); `useful` = MODEL_FLOPS / loop-aware "
           "HLO_FLOPs.",
           "",
           "| arch | shape | compute | memory (lb..ub) | collective | "
           "bottleneck | useful | frac | multi-pod OK |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(singles, key=lambda r: (r["arch"], r["shape"])):
        rf = r["roofline"]
        mp = "yes" if (r["arch"], r["shape"]) in multis else "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(rf['t_compute_s'])} "
            f"| {fmt(rf.get('t_memory_lb_s', 0))}..{fmt(rf['t_memory_s'])} "
            f"| {fmt(rf['t_collective_s'])} | {rf['dominant_lb']} "
            f"| {rf['useful_flops_frac']:.2f} | {mfu_lb(rf):.3f} | {mp} |")
    # per-cell bottleneck sentence requirements -> summarized
    out += ["",
            "**Bottleneck notes (what moves the dominant term down).** "
            "TRAIN cells: dominated by TP-activation all-reduces + FSDP "
            "gathers -> fewer/wider collectives (the §Perf iterations), "
            "int8 gradient reduction, or more data-parallel share. "
            "PREFILL cells: compute- or collective-bound -> sequence "
            "parallelism with replicated weights for <10B archs (§Perf A). "
            "DECODE cells: memory-bound on weights+KV reads (the paper's "
            "Figure 1 premise, visible here) -> int8 KV (§Perf C), larger "
            "co-located batches — exactly the slack FlexNPU's scheduler "
            "exploits by lending decode's spare compute to prefill. "
            "long_500k SSM/hybrid cells: state/cache streaming bound; "
            "mamba2's O(1) state makes decode nearly free (DESIGN.md §4 "
            "applicability note).",
            ""]
    return "\n".join(out)


def perf_section() -> str:
    recs = {}
    for f in glob.glob(os.path.join(ROOT, "results/perf/*.json")):
        with open(f) as fh:
            d = json.load(fh)
        key = os.path.basename(f).split("__")[0]
        recs.setdefault(key, {})[d["variant"]] = d

    def row(cell, variant):
        d = recs[cell][variant]
        rf = d["roofline"]
        return (f"| {variant} | {fmt(rf['t_compute_s'])} "
                f"| {fmt(rf.get('t_memory_lb_s', 0))} "
                f"| {fmt(rf['t_collective_s'])} | {fmt(lb_step(rf))} "
                f"| {mfu_lb(rf):.4f} |")

    hdr = ("| variant | compute | memory(lb) | collective | step(lb) | "
           "roofline frac |\n|---|---|---|---|---|---|")
    s = []
    s.append("### Cell A — starcoder2-3b x prefill_32k "
             "(worst roofline fraction + most collective-bound)\n")
    s.append("Original baseline (pre-fix sweep): collective term **125 s** "
             "vs compute 0.37 s (roofline fraction 0.001).  Diagnosis from "
             "the lowered HLO: 24 q-heads don't divide tp=16, so activations "
             "fell back to head_dim sharding; contracting a SHARDED head_dim "
             "inside the q/kv block scans emits a psum per block x2048 "
             "executions/layer.\n")
    s.append(hdr)
    for v in ["baseline", "no_headdim_shard", "seqpar_repl_weights",
              "seqpar_kv_sharded"]:
        if v in recs.get("A", {}):
            s.append(row("A", v))
    s.append("")
    s.append(
        "* H-A1 (global fix, now the default rules): never shard ACTIVATION "
        "head_dim (weights may stay head_dim-sharded — gathered once). "
        "Predicted ~100x collective drop; **confirmed** — 125 s -> ~1 s "
        "collective on this cell and large drops across the whole sweep "
        "(compare results/dryrun_sweep.log vs _v2.log).\n"
        "* H-A2 `seqpar_repl_weights`: replicate the 6 GB weights, shard the "
        "32k SEQUENCE over `model` (single-q-block attention), gather k/v "
        "once per layer.  Predicted compute-bound at ~0.6 s; **confirmed "
        "direction** (86x total step win vs original): compute 0.58 s, "
        "collective 1.45 s (GSPMD gathers h-sized tensors per layer, "
        "72 GB).\n"
        "* H-A3 `seqpar_kv_sharded`: keep k/v seq-sharded so only kv-block "
        "slices gather inside the scan.  See table — further reduces the "
        "gather volume toward the 4 GB prediction.\n")
    s.append("### Cell B — grok-1-314b x train_4k (large-MoE training, "
             "the paper's DeepSeek-R1-class regime)\n")
    s.append(hdr)
    for v in ["baseline", "megatron_sp", "sp_plus_small_vocab_repl",
              "no_remat"]:
        if v in recs.get("B", {}):
            s.append(row("B", v))
    s.append("")
    s.append(
        "* H-B1 `megatron_sp` (seq-shard the residual carry): predicted "
        "1.5-2x collective reduction; **REFUTED** — collectives rose ~1.5x. "
        "The compiler log shows why: `[SPMD] Involuntary full "
        "rematerialization ... cannot go from {1,1,1,16} to {1,1,8,1,2}` on "
        "the GQA attention dots — the seq-sharded carry conflicts with "
        "head-sharded attention and GSPMD replicates tensors to reshard. "
        "Lesson: carry-only SP needs per-op resharding support (Shardy) — "
        "a refuted hypothesis worth exactly as much as a confirmed one.\n"
        "* H-B2 `no_remat`: predicted -33% collective bytes (the remat pass "
        "re-executes every TP psum); **confirmed on collectives** "
        "(36.7 s -> 28.7 s, -22%) but **refuted on memory**: "
        "temp 168 GB -> 2.4 TB/device, far past HBM.  The viable form is "
        "selective remat (save only TP-reduced outputs), noted as future "
        "work.\n"
        "* Net effect kept for B: the H-A1 global rules fix "
        "(69.8 s -> 36.7 s collective, roofline fraction 0.14 -> 0.27).\n")
    s.append("### Cell C — mixtral-8x7b x decode_32k (most representative "
             "of the paper's technique: the decode phase FlexNPU schedules)\n")
    s.append(hdr)
    for v in ["baseline", "seq_sharded_cache", "seq_cache_repl_q",
              "seq_cache_int8_kv"]:
        if v in recs.get("C", {}):
            s.append(row("C", v))
    s.append("")
    s.append(
        "* H-C1 `seq_sharded_cache` (flash-decoding layout): kv_heads=8 "
        "don't divide tp=16, so the baseline re-gathered cache slices every "
        "step (2.2 GB/step wire).  Sharding the cache by SEQUENCE over "
        "`model` turns that into tiny partial-softmax stat psums.  "
        "Predicted ~100x; **confirmed**: 47 ms -> 4.2 ms collective "
        "(11x step win), cell is now memory-bound at its true floor "
        "(weights+KV read) — adopted into the default serve rules.\n"
        "* H-C2 `int8 KV cache`: decode reads ~34 GB of KV per step at "
        "32k x 128; int8 halves it.  Step lower bound drops accordingly "
        "(see table) at ~1-quantization-step accuracy cost "
        "(tests/test_layers.py).\n"
        "* Perf-relevant consequence for the PAPER's scheduler: post-fix, "
        "decode is memory-bound with idle MXU — precisely the compute slack "
        "(Figure 2) that dynamic PD co-location lends to prefill.\n")
    s.append("### Stopping criterion\n")
    s.append("Three consecutive <5% iterations were reached on cells A and "
             "C (further variants moved the dominant term <5%); cell B's "
             "remaining ideas (selective remat, Shardy-based SP, wire-level "
             "int8 gradient reduce-scatter — implemented as "
             "`repro.distributed.collectives.compressed_psum_local` but not "
             "lowerable through GSPMD rules alone) are documented above.\n")
    return "\n".join(s)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_section())
    text = text.replace("<!-- PERF_LOG -->", perf_section())
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
