"""Kernel microbenchmarks: Pallas (interpret on CPU / Mosaic on TPU) vs the
pure-jnp oracle, plus the XLA blocked-attention path used by the dry-run."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(quick: bool = False):
    from repro.kernels import flash_attention, paged_attention, ssd_scan
    from repro.kernels import ref as R
    from repro.models.layers import blocked_attention

    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # decode paged attention
    B, H, KVH, D, ps, maxp = 4, 8, 2, 64, 16, 8
    P = B * maxp
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (P, ps, KVH, D))
    vp = jax.random.normal(ks[2], (P, ps, KVH, D))
    pt = jnp.arange(P, dtype=jnp.int32).reshape(B, maxp)
    ln = jnp.full((B,), ps * maxp, jnp.int32)
    us_ref = _time(lambda: R.ref_paged_attention(q, kp, vp, pt, ln, scale=0.125))
    us_pal = _time(lambda: paged_attention(q, kp, vp, pt, ln, scale=0.125,
                                           interpret=True))
    rows.append(("kernel.paged_attention.ref_jnp", us_ref, {}))
    rows.append(("kernel.paged_attention.pallas_interpret", us_pal,
                 {"note": "interpret mode timing is NOT TPU perf"}))

    # prefill flash attention
    S = 256 if quick else 512
    q2 = jax.random.normal(ks[3], (2, S, 4, 64))
    k2 = jax.random.normal(ks[4], (2, S, 2, 64))
    v2 = jax.random.normal(ks[5], (2, S, 2, 64))
    us_ref = _time(lambda: R.ref_flash_attention(q2, k2, v2, scale=0.125))
    us_xla = _time(lambda: blocked_attention(q2, k2, v2, causal=True,
                                             scale=0.125, block_q=128,
                                             block_kv=128))
    rows.append(("kernel.flash_attention.ref_jnp_dense", us_ref, {}))
    rows.append(("kernel.flash_attention.xla_blocked", us_xla,
                 {"speed_vs_dense": round(us_ref / us_xla, 2)}))

    # ssd scan
    S3 = 512 if quick else 1024
    x = jax.random.normal(ks[6], (2, S3, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[7], (2, S3, 4)))
    A = -jnp.exp(jax.random.normal(key, (4,)) * 0.3)
    Bm = jax.random.normal(ks[1], (2, S3, 1, 32)) * 0.5
    Cm = jax.random.normal(ks[2], (2, S3, 1, 32)) * 0.5
    from repro.models.mamba2 import ssd_chunked
    us_seq = _time(lambda: R.ref_ssd(x, dt, A, Bm, Cm))
    us_chunk = _time(lambda: ssd_chunked(x, dt, A, Bm, Cm, 128))
    rows.append(("kernel.ssd.sequential_ref", us_seq, {}))
    rows.append(("kernel.ssd.chunked_xla", us_chunk,
                 {"speed_vs_sequential": round(us_seq / us_chunk, 2)}))
    return rows
