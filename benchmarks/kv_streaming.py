"""Chunked layer-wise KV streaming vs one-blob transfers, across topologies.

The disaggregation KV path (repro.transport) is swept along two axes:

  * **topology** — ``flat`` (destination-ingress contention only, the v2
    model) vs ``shared_spine`` (source egress -> shared spine -> ingress;
    every transfer occupies its full path, so cross-pair flows contend on
    the spine — the dominant fabric cost at pod scale, cf. the
    inter-core-connected-NPU studies in PAPERS.md);
  * **chunking** — one blob per request (``kv_chunk_tokens=0``) vs
    layer-wise chunks pipelined over ``memcpy_peer``.

The deployment is sized so prefill-side KV capacity binds (7-chip prefill
instances barely fit the weights): with one-blob transfers a slow spine
holds every request's pages hostage for the whole transfer and parked
prefills wait; chunked streaming frees source pages chunk-by-chunk and
admits decode on the FIRST chunk.  Expected: chunked reduces TTFT (and
time-to-second-token, the client-visible transfer cost) at equal
throughput on the bandwidth-constrained spine, with the contention
attributed to the spine segment in the per-link stats — and the decode
stalls it introduces (decode outrunning the tail) made visible.
"""
from __future__ import annotations

import copy

# (topology name, knobs) — spine_bw chosen so the spine, not the ingress,
# is the contended segment in the constrained sweep
TOPOLOGIES = (
    ("flat", {}),
    ("shared_spine", dict(ingress_bw=50e9, egress_bw=50e9, spine_bw=1.5e9)),
)
CHUNK_TOKENS = (0, 512)


def _deploy():
    from repro.serving import DeploymentSpec
    # 6P2D geometry with prefill instances sized to the KV-capacity edge
    return DeploymentSpec(mode="disagg", prefill_instances=6,
                          prefill_chips=7, decode_instances=2,
                          decode_chips=144)


def _workload(quick: bool):
    from repro.serving import make_workload
    # even the quick run must cross the prefill KV-capacity edge (~13
    # parked 4096-token prompts per 7-chip instance) or the TTFT effect of
    # per-chunk page freeing has nothing to bite on
    n = 90 if quick else 120
    return make_workload(n, 4096, 64, rate=1e5, seed=7)


def run(quick: bool = False, chunks=CHUNK_TOKENS, topologies=TOPOLOGIES):
    from repro.configs import get_config
    from repro.serving import Cluster, SimConfig
    from repro.transport import make_topology

    cfg = get_config("mixtral-8x7b")
    wl = _workload(quick)
    rows = []
    for topo_name, knobs in topologies:
        baseline = None
        for chunk in chunks:
            sim = SimConfig(topology=make_topology(topo_name, **knobs),
                            kv_chunk_tokens=chunk)
            cluster = Cluster(cfg, _deploy(), sim_cfg=sim)
            res = cluster.run(copy.deepcopy(wl), until=72000)
            cluster.check_kv_conservation()
            per_link = res.get("per_link", {})
            spine_qd = sum(v["queue_delay_s"] for k, v in per_link.items()
                           if k.startswith("spine:"))
            ingress_qd = sum(v["queue_delay_s"] for k, v in per_link.items()
                             if k.startswith("ingress:"))
            derived = {
                "topology": topo_name,
                "kv_chunk_tokens": chunk,
                "completed": res["completed"],
                "rps": round(res["requests_per_s"], 3),
                "ttft_mean_s": round(res["ttft_mean_s"], 3),
                "ttft_p95_s": round(res["ttft_p95_s"], 3),
                "ttst_mean_s": round(res["ttst_mean_s"], 3),
                "ttst_p95_s": round(res["ttst_p95_s"], 3),
                "transfers": res.get("transfers", 0),
                "decode_stall_s": res.get("decode_stall_s", 0.0),
                "decode_stalls": res.get("decode_stalls", 0),
                # contention attribution: spine vs ingress queueing (the
                # per-segment breakdown the flat model could not produce)
                "spine_queue_delay_s": round(spine_qd, 3),
                "ingress_queue_delay_s": round(ingress_qd, 3),
            }
            if baseline is None:
                baseline = res
            else:
                derived["ttft_vs_blob"] = "{:+.2%}".format(
                    res["ttft_mean_s"] / baseline["ttft_mean_s"] - 1)
                derived["ttst_vs_blob"] = "{:+.2%}".format(
                    res["ttst_mean_s"] / baseline["ttst_mean_s"] - 1)
                derived["rps_vs_blob"] = "{:+.2%}".format(
                    res["requests_per_s"] / baseline["requests_per_s"] - 1)
            rows.append((f"kv_streaming.{topo_name}.chunk{chunk}",
                         1e6 / max(res["requests_per_s"], 1e-9), derived))
    return rows


def main(argv=None) -> None:
    import argparse

    from benchmarks._cli import emit_rows

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny workload")
    ap.add_argument("--chunks", default=",".join(map(str, CHUNK_TOKENS)),
                    help="comma-separated kv_chunk_tokens values "
                         "(0 = one blob; first is the comparison baseline)")
    ap.add_argument("--topology", default="",
                    help="run one topology only (flat | shared_spine)")
    ap.add_argument("--json", default="",
                    help="also write the rows to this JSON file")
    args = ap.parse_args(argv)
    topologies = tuple(t for t in TOPOLOGIES
                       if not args.topology or t[0] == args.topology)
    chunks = tuple(int(c) for c in args.chunks.split(",") if c != "")
    rows = run(quick=args.quick or args.smoke, chunks=chunks,
               topologies=topologies)
    emit_rows(rows, args.json)


if __name__ == "__main__":
    main()
