"""Dynamic instance role-switching vs the static 6P2D deployment.

A bursty, phase-shifted workload (dense long-prompt prefill bursts
alternating with decode-heavy tails) is exactly where a static
prefill/decode split is mis-provisioned in BOTH halves of every cycle.
The ``role_switch`` cluster policy keeps the same 384-chip 6P2D geometry
but lets a decode instance flip to prefill under TTFT pressure — draining
its in-flight decode KV over the copy-engine path — and flip back when
the pressure subsides.  Expected: throughput >= the static baseline with
a much lower p95 TTFT, in BOTH drive modes (stepped discrete-event and
threaded real-daemon dispatch).

Policies are swept by registry name (``--policies least_loaded,role_switch``
— ``least_loaded`` on the 6P2D geometry IS the static baseline), and each
row's derived JSON carries the cluster's policy telemetry (role flips,
realized pressure, queue depths) so BENCH artifacts record policy
*behavior*, not just throughput.
"""
from __future__ import annotations

import copy

DRIVES = ("stepped", "threaded")
DEFAULT_POLICIES = ("least_loaded", "role_switch")
ROLE_KNOBS = dict(ttft_hi_s=0.5, ttft_lo_s=0.2, cooldown_s=2.0)


def _workload(quick: bool):
    from repro.serving import bursty_phase_shift
    if quick:
        return bursty_phase_shift(
            n_bursts=2, burst_gap_s=12.0, n_prefill=150, prefill_rate=600.0,
            prefill_io=(4096, 64), n_decode=40, decode_rate=8.0,
            decode_io=(128, 512), seed=5)
    return bursty_phase_shift(
        n_bursts=2, burst_gap_s=25.0, n_prefill=300, prefill_rate=600.0,
        prefill_io=(4096, 64), n_decode=100, decode_rate=10.0,
        decode_io=(128, 1024), seed=5)


def _deploy(policy: str):
    from repro.serving import deployment_6p2d, deployment_role_switch
    if policy == "least_loaded":
        return deployment_6p2d()
    if policy == "role_switch":
        return deployment_role_switch(**ROLE_KNOBS)
    import dataclasses
    return dataclasses.replace(deployment_6p2d(), cluster_policy=policy)


def run(quick: bool = False, drives=DRIVES, policies=DEFAULT_POLICIES):
    from repro.configs import get_config
    from repro.serving import Cluster, SimConfig

    cfg = get_config("mixtral-8x7b")
    rows = []
    for drive in drives:
        # the threaded drive always uses the smaller workload: real dispatch
        # overhead (thread handoffs, GIL) must stay well below the modeled
        # op durations for the wall clock to reproduce the stepped dynamics,
        # which bounds how much work a single host can drive faithfully
        wl = _workload(quick or drive == "threaded")
        baseline = None
        for policy in policies:
            sim = SimConfig(prefill_window=4)
            # threaded: a larger time_scale keeps modeled durations well
            # above real dispatch overhead (sleep granularity, GIL), so
            # the drive reproduces the stepped dynamics instead of noise
            cluster = Cluster(cfg, _deploy(policy), sim_cfg=sim, drive=drive,
                              time_scale=0.1)
            res = cluster.run(copy.deepcopy(wl), until=36000)
            if drive == "stepped":
                cluster.check_kv_conservation()
            tele = res["policy"]
            derived = {
                "drive": drive,
                "policy": policy,
                "completed": res["completed"],
                "rps": round(res["requests_per_s"], 2),
                "tokens_per_s": round(res["output_tokens_per_s"], 0),
                "ttft_mean_s": round(res["ttft_mean_s"], 3),
                "ttft_p95_s": round(res["ttft_p95_s"], 3),
                "tpot_mean_s": round(res["tpot_mean_s"], 4),
                "transfers": res.get("transfers", 0),
                # control-plane telemetry (satellite: BENCH artifacts must
                # record policy behavior, not just throughput)
                "role_flips": tele["role_flips"],
                "roles_final": tele["roles"],
                "cluster_policy": tele["cluster"],
            }
            if baseline is None:
                baseline = res
            else:
                derived["throughput_vs_static"] = "{:+.2%}".format(
                    res["requests_per_s"] / baseline["requests_per_s"] - 1)
                derived["ttft_p95_vs_static"] = "{:+.2%}".format(
                    res["ttft_p95_s"] / baseline["ttft_p95_s"] - 1)
            rows.append((f"role_switch.{drive}.{policy}",
                         1e6 / max(res["requests_per_s"], 1e-9), derived))
    return rows


def main(argv=None) -> None:
    import argparse

    from benchmarks._cli import emit_rows

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny workload, both drive modes")
    ap.add_argument("--drive", default="", choices=["", *DRIVES],
                    help="run one drive mode only (default: both)")
    ap.add_argument("--policies", default=",".join(DEFAULT_POLICIES),
                    help="comma-separated cluster-policy registry names "
                         "(first is the comparison baseline)")
    ap.add_argument("--json", default="",
                    help="also write the rows to this JSON file")
    args = ap.parse_args(argv)
    drives = (args.drive,) if args.drive else DRIVES
    rows = run(quick=args.quick or args.smoke, drives=drives,
               policies=tuple(p for p in args.policies.split(",") if p))
    emit_rows(rows, args.json)


if __name__ == "__main__":
    main()
