"""Figure 2 — decode memory-bandwidth utilization vs AI-core allocation.

The paper's motivating measurement: during decode, HBM utilization rises
with allocated compute units and then saturates — past the knee, extra
compute buys no decode throughput (the slack FlexNPU lends to prefill).
Modeled with the v5e roofline; the knee position is the compute:memory
ratio of the decode step."""
from __future__ import annotations


def run(quick: bool = False):
    from repro.configs import get_config
    from repro.serving.costmodel import CostModel, InstanceSpec

    cfg = get_config("mixtral-8x7b")
    cm = CostModel(cfg)
    spec = InstanceSpec("fig2", chips=8)
    rows = []
    prev = None
    knee = None
    for cores in [0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.8, 1.0]:
        util = cm.decode_bandwidth_utilization(cores, batch=128,
                                               avg_context=2048, spec=spec)
        if prev is not None and knee is None and util - prev < 0.02:
            knee = cores
        rows.append((f"fig2.bw_util.cores_{int(cores * 100)}pct",
                     1e6 * (1 - util + 1e-9),
                     {"core_fraction": cores, "hbm_utilization": round(util, 4)}))
        prev = util
    rows.append(("fig2.saturation_knee", 0.0,
                 {"knee_core_fraction": knee,
                  "interpretation": "beyond the knee extra compute gives "
                                    "decode no additional bandwidth"}))
    return rows
