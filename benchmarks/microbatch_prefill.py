"""Micro-batched prefill co-location across execution-queue configs.

The execution-queue engine model (repro.core.queues) is swept along two
axes, in BOTH drive modes (stepped discrete-event and threaded real-daemon
dispatch):

  * **queue count** — ``compute x 1`` (the v3 engine-slot model: decode
    serializes behind every prefill launch on the single compute queue)
    vs ``compute x 2+`` (decode pinned to its own queue; prefill streams
    on the rest; concurrent compute ops split modeled FLOP throughput in
    proportion to their compute-boundedness);
  * **micro-batching** — whole-prompt prefill launches vs
    ``chunk_prefill_tokens``-sized chunks (chunks of one request stay
    FIFO on one stream; decode interleaves between and, with a second
    queue, alongside them).

Expected: with ``compute x 2`` and chunked prefill, decode TPOT
degradation under co-located prefill drops versus the single-queue
baseline at equal or better throughput — prefill is compute-bound and
decode bandwidth-bound, so the queue layer converts their complementary
bottlenecks into overlap (the paper's co-location claim, now visible at
the dispatch layer).  TTFT rises slightly with chunking (each chunk pays
a launch overhead) — the benchmark reports it so the trade is explicit.
"""
from __future__ import annotations

import copy

DRIVES = ("stepped", "threaded")
CHUNK = 2048
# (label, compute_queues, chunk_prefill_tokens); the SECOND row (single
# queue, micro-batched) is the comparison baseline for the queue-count
# claim — rows are also compared against the first (the v3 engine).
CONFIGS = (
    ("q1", 1, 0),
    ("q1_mb", 1, CHUNK),
    ("q2_mb", 2, CHUNK),
    ("q3_mb", 3, CHUNK),
)


def _workload(quick: bool):
    from repro.serving import make_workload
    # steady long-prompt arrivals over an active decode population: every
    # decode step races a co-located prefill chunk, which is exactly the
    # TPOT interference the extra compute queue removes.  Prompts are long
    # (8k) so the interference dominates scheduling noise in BOTH drives.
    if quick:
        return make_workload(20, 8192, 96, rate=40.0, seed=3)
    return make_workload(60, 8192, 128, rate=40.0, seed=3)


def run(quick: bool = False, drives=DRIVES, configs=CONFIGS):
    from repro.configs import get_config
    from repro.serving import Cluster, SimConfig, deployment_dynamic

    cfg = get_config("mixtral-8x7b")
    rows = []
    for drive in drives:
        # threaded: smaller workload + a larger time_scale so modeled op
        # durations stay well above this host's sleep granularity even
        # after the calibrated-overhead subtraction (see role_switch)
        wl = _workload(quick or drive == "threaded")
        ref = base = None
        for label, cq, chunk in configs:
            sim = SimConfig(compute_queues=cq, chunk_prefill_tokens=chunk)
            cluster = Cluster(cfg, deployment_dynamic(instances=1),
                              sim_cfg=sim, drive=drive, time_scale=0.5)
            res = cluster.run(copy.deepcopy(wl), until=72000)
            if drive == "stepped":
                cluster.check_kv_conservation()
            derived = {
                "drive": drive,
                "config": label,
                "compute_queues": cq,
                "chunk_prefill_tokens": chunk,
                "completed": res["completed"],
                "rps": round(res["requests_per_s"], 3),
                "tokens_per_s": round(res["output_tokens_per_s"], 0),
                "ttft_mean_s": round(res["ttft_mean_s"], 4),
                "ttft_p95_s": round(res["ttft_p95_s"], 4),
                "tpot_mean_s": round(res["tpot_mean_s"], 6),
                "tpot_p99_s": round(res["tpot_p99_s"], 6),
            }
            if drive == "threaded" and "calibration" in res:
                derived["calibration"] = res["calibration"]
            if ref is None:
                ref = res                     # q1: the v3 engine reference
            else:
                derived["tpot_vs_q1"] = "{:+.2%}".format(
                    res["tpot_mean_s"] / ref["tpot_mean_s"] - 1)
                derived["rps_vs_q1"] = "{:+.2%}".format(
                    res["requests_per_s"] / ref["requests_per_s"] - 1)
            if label == "q1_mb":
                base = res                    # single-queue micro-batched
            elif base is not None:
                # the headline: same micro-batching, extra queue(s)
                derived["tpot_vs_single_queue"] = "{:+.2%}".format(
                    res["tpot_mean_s"] / base["tpot_mean_s"] - 1)
                derived["tpot_p99_vs_single_queue"] = "{:+.2%}".format(
                    res["tpot_p99_s"] / base["tpot_p99_s"] - 1)
                derived["rps_vs_single_queue"] = "{:+.2%}".format(
                    res["requests_per_s"] / base["requests_per_s"] - 1)
            rows.append((f"microbatch_prefill.{drive}.{label}",
                         1e6 / max(res["requests_per_s"], 1e-9), derived))
    return rows


def main(argv=None) -> None:
    import argparse

    from benchmarks._cli import emit_rows

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny workload")
    ap.add_argument("--drive", default="",
                    help="run one drive only (stepped | threaded)")
    ap.add_argument("--queues", default="",
                    help="comma-separated compute-queue counts to sweep, "
                         f"each micro-batched at {CHUNK} tokens; an "
                         "unchunked compute-x-1 reference row is always "
                         "prepended, and including 1 yields the q1_mb "
                         "single-queue baseline the vs-columns compare to")
    ap.add_argument("--json", default="",
                    help="also write the rows to this JSON file")
    args = ap.parse_args(argv)
    drives = tuple(d for d in DRIVES if not args.drive or d == args.drive)
    configs = CONFIGS
    if args.queues:
        counts = [int(c) for c in args.queues.split(",") if c != ""]
        configs = (("q1", 1, 0),) + tuple(
            (f"q{c}_mb", c, CHUNK) for c in counts)
    rows = run(quick=args.quick or args.smoke, drives=drives,
               configs=configs)
    emit_rows(rows, args.json)


if __name__ == "__main__":
    main()
