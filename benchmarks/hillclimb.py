import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> re-analyse.

Three cells (per the assignment: worst roofline fraction, most
collective-bound, most representative of the paper's technique), each with
named layout variants applied through sharding-rule OVERRIDES — the model
code is untouched; only the layout changes, which is exactly the lever a
framework operator has.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell A --variant seqpar
    PYTHONPATH=src python -m benchmarks.hillclimb --all
"""
import argparse
import json

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "perf")

M = (("model",),)
NONE = ((),)

CELLS = {
    # A: worst roofline fraction + most collective-bound.
    # baseline: 24 q heads don't divide tp=16 -> head_dim-sharded attention
    # puts a psum over the contracted head_dim INSIDE the q/kv block scans
    # (x2048 executions/layer).
    "A": {
        "arch": "starcoder2-3b", "shape": "prefill_32k",
        "variants": {
            # H1: replicate the (small, 6GB bf16) weights; shard the 32k
            # SEQUENCE over `model` instead; k/v gathered once per layer.
            # Predicted: collective term 125s -> O(0.1s) (seq-gathers only),
            # compute term unchanged -> compute-bound.
            "seqpar_repl_weights": dict(
                overrides={"mlp": NONE, "heads": NONE, "kv_heads": NONE,
                           "head_dim": NONE, "vocab": NONE, "embed": NONE,
                           "act_heads": NONE, "act_head_dim": NONE,
                           "act_vocab": NONE, "act_seq": M,
                           "cache_seq": (("data",), ("model",), ()),
                           "cache_kv_heads": NONE, "cache_head_dim": NONE},
                flags=("single_q_block",)),
            # H2 (ablation): only stop head_dim sharding, keep TP elsewhere.
            # Predicted: kills the in-scan psums but re-replicates attention
            # compute -> partial win.
            "no_headdim_shard": dict(
                overrides={"head_dim": NONE, "act_head_dim": NONE,
                           "cache_head_dim": NONE, "kv_heads": NONE}),
        },
    },
    # B: large-MoE training (the paper's DeepSeek-R1-class regime).
    # baseline: TP activation all-reduces dominate (1.8TB/dev/step).
    "B": {
        "arch": "grok-1-314b", "shape": "train_4k",
        "variants": {
            # H3: Megatron-style sequence parallelism — the residual carry is
            # seq-sharded over `model`; XLA turns layer-boundary all-reduces
            # into reduce-scatter + all-gather and the remat carry shrinks
            # 16x.  Predicted: collective bytes down ~1.5-2x, memory down.
            "megatron_sp": dict(overrides={"act_seq": M}),
            # H4 (ablation): shard the MoE dispatch chunk over data instead
            # of replicating routed activations.
            "sp_plus_small_vocab_repl": dict(
                overrides={"act_seq": M, "act_vocab": NONE}),
        },
    },
    # C: most representative of the paper's technique: the DECODE phase that
    # FlexNPU schedules around.  baseline: q is head-sharded but kv_heads=8
    # don't divide tp=16 so the KV cache is head_dim-sharded -> GSPMD
    # re-gathers cache slices every step (2.2GB/step wire).
    "C": {
        "arch": "mixtral-8x7b", "shape": "decode_32k",
        "variants": {
            # H5: shard the cache by SEQUENCE over `model` (flash-decoding
            # style): per-shard partial attention + tiny psum of [B,H,D]
            # output stats.  Predicted: collective 2.2GB -> tens of MB.
            "seq_sharded_cache": dict(
                overrides={"cache_seq": (("data",), ("model",), ()),
                           "cache_head_dim": NONE, "cache_kv_heads": NONE}),
            # H6: + keep q replicated across model to avoid the q reshard
            # before the cache contraction.
            "seq_cache_repl_q": dict(
                overrides={"cache_seq": (("data",), ("model",), ()),
                           "cache_head_dim": NONE, "cache_kv_heads": NONE,
                           "act_heads": NONE, "act_head_dim": NONE}),
            # H7 (round 2): int8 KV cache on top of the seq-sharded layout —
            # decode is KV-read-bound, so halving cache bytes should halve
            # the (now-dominant) memory term.
            "seq_cache_int8_kv": dict(
                overrides={"cache_seq": (("data",), ("model",), ()),
                           "cache_head_dim": NONE, "cache_kv_heads": NONE},
                cfg_overrides={"kv_cache_dtype": "int8"}),
        },
    },
}

# round-2 additions
CELLS["B"]["variants"]["no_remat"] = dict(
    # H8: drop remat — the recompute pass re-executes every TP psum
    # (+50% collective bytes); without it the scan saves one residual per
    # layer ([16,4096,6144] bf16 x 64L ~= 6.4GB/dev after batch sharding).
    cfg_overrides={"remat": False})

# round-3 additions
CELLS["A"]["variants"]["seqpar_kv_sharded"] = dict(
    # H9: like H1 but k/v stay sequence-sharded — GSPMD gathers 1MB kv-block
    # slices inside the scan instead of (what H1's HLO shows) re-gathering
    # h-sized tensors per layer.  Predicted: all-gather 72GB -> ~4GB.
    overrides={"mlp": NONE, "heads": NONE, "kv_heads": NONE,
               "head_dim": NONE, "vocab": NONE, "embed": NONE,
               "act_heads": NONE, "act_head_dim": NONE,
               "act_vocab": NONE, "act_seq": M,
               "cache_seq": (("data",), ("model",), ()),
               "cache_kv_heads": NONE, "cache_head_dim": NONE},
    flags=("single_q_block", "kv_seq_sharded"))


def run_variant(cell_key: str, variant: str):
    from repro.launch.dryrun import lower_cell, roofline_terms
    cell = CELLS[cell_key]
    kw = {}
    if variant != "baseline":
        spec = cell["variants"][variant]
        kw = dict(rule_overrides=spec.get("overrides"),
                  flags=spec.get("flags", ()),
                  cfg_overrides=spec.get("cfg_overrides"))
    compiled, info = lower_cell(cell["arch"], cell["shape"], multi_pod=False,
                                **kw)
    info["roofline"] = roofline_terms(info)
    info["variant"] = variant
    os.makedirs(RESULTS, exist_ok=True)
    fname = f"{cell_key}__{cell['arch']}__{cell['shape']}__{variant}.json"
    with open(os.path.join(RESULTS, fname), "w") as f:
        json.dump(info, f, indent=1)
    rf = info["roofline"]
    print(f"[{cell_key}/{variant}] {cell['arch']} x {cell['shape']}: "
          f"compute={rf['t_compute_s']:.2e}s "
          f"mem_lb={rf['t_memory_lb_s']:.2e}s "
          f"coll={rf['t_collective_s']:.2e}s "
          f"dominant={rf['dominant_lb']} mfu_bound={rf['mfu_bound']:.4f} "
          f"(compile {info['compile_s']}s)")
    del compiled
    return info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(CELLS))
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    cells = list(CELLS) if args.all or not args.cell else [args.cell]
    for ck in cells:
        variants = (["baseline"] + list(CELLS[ck]["variants"])) \
            if not args.variant else [args.variant]
        for v in variants:
            try:
                run_variant(ck, v)
            except Exception as e:
                print(f"[{ck}/{v}] FAILED: {e!r}")


if __name__ == "__main__":
    main()
