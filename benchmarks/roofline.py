"""§Roofline — renders the per-(arch x shape x mesh) roofline table from the
dry-run JSONs (results/dryrun/*.json; produced by repro.launch.dryrun).

Terms (per device, loop-aware HLO accounting — see launch/hlo_analysis.py):
  compute    = HLO_dot_FLOPs / 197e12
  memory     = HLO buffer-level bytes / 819e9     (upper bound)
  memory_lb  = analytic ideal bytes / 819e9       (lower bound)
  collective = collective wire bytes / 50e9
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_all():
    out = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def markdown_table(records, mesh_filter="single_pod_16x16"):
    lines = [
        "| arch | shape | t_compute | t_memory (lb..ub) | t_collective | "
        "dominant | useful_flops | MFU bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r["mesh"] != mesh_filter:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rf['t_compute_s']:.2e} "
            f"| {rf.get('t_memory_lb_s', 0):.2e}..{rf['t_memory_s']:.2e} "
            f"| {rf['t_collective_s']:.2e} "
            f"| {rf['dominant_lb']}/{rf['dominant']} "
            f"| {rf['useful_flops_frac']:.2f} "
            f"| {rf.get('mfu_bound', 0):.3f} |")
    return "\n".join(lines)


def run(quick: bool = False):
    records = load_all()
    rows = []
    for r in records:
        rf = r["roofline"]
        step = max(rf["t_compute_s"], rf.get("t_memory_lb_s", 0.0),
                   rf["t_collective_s"])
        rows.append((
            f"roofline.{r['arch']}.{r['shape']}."
            f"{'multi' if 'multi' in r['mesh'] else 'single'}",
            step * 1e6,
            {"dominant": rf["dominant_lb"],
             "t_compute_s": f"{rf['t_compute_s']:.3e}",
             "t_memory_lb_s": f"{rf.get('t_memory_lb_s', 0):.3e}",
             "t_memory_ub_s": f"{rf['t_memory_s']:.3e}",
             "t_collective_s": f"{rf['t_collective_s']:.3e}",
             "useful_flops_frac": round(rf["useful_flops_frac"], 3),
             "mfu_bound": round(rf.get("mfu_bound", 0), 4)}))
    if not rows:
        rows.append(("roofline.missing", 0.0,
                     {"note": "run `python -m repro.launch.dryrun --all "
                              "--mesh both` first"}))
    return rows


if __name__ == "__main__":
    print(markdown_table(load_all()))
