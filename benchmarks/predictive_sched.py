"""Predictive scheduling (v9): learned models vs blind/SLO-aware planes.

Two traffic shapes stress two different prediction surfaces:

* ``tiered_burst`` — the slo_attainment mix (Zipf over 256..4096-token
  prompt classes, MMPP 10x flash crowd, tiered tenants).  Under the
  burst the waiting queue is long and HEAVY-TAILED, so ordering is
  everything: predicted-SJF admission/dispatch stops short interactive
  prompts from queueing behind 4k-token monsters, JBSQ keeps one
  instance from hoarding the predicted work, and the TTFT tail drops.
* ``multi_turn`` — shared-prefix chat whose prompts GROW turn over
  turn: a live spread of service times with no tenant tiers at all,
  i.e. the predictive stack must win on learned sizes alone.

Arms (first two are the comparison baselines):

* ``fifo``          — mode defaults: FIFO dispatch, least-loaded
                      routing, ungated admission.  The tenant- and
                      size-blind v4 control plane.
* ``slo_aware``     — the v5 tiered admission plane (strict priority +
                      stride fairness), still size-blind.
* ``predictive``    — the full v9 stack: ``ridge_latency`` bootstrap-fit
                      from the cost model + online observation,
                      ``length_quantile`` sketches, ``predicted_sjf``
                      dispatch, ``jbsq`` routing, ``predictive``
                      admission, adaptive prefill chunking.

Expected (the PR's acceptance bar, asserted in the tiered_burst
predictive row's derived JSON): p95 TTFT cut by >= 15% against the BEST
non-predictive baseline at >= 0.99x its best token throughput — in both
drive modes — with the latency model's calibration (MAPE) recorded in
the same artifact.  Every row carries the conservation invariant
``completed + rejected + failed == generated``.
"""
from __future__ import annotations

import copy

DRIVES = ("stepped", "threaded")
DEFAULT_POLICIES = ("fifo", "slo_aware", "predictive")
WORKLOADS = ("tiered_burst", "multi_turn")
TTFT_SCALE = 0.5
# acceptance bar: p95 TTFT <= (1 - CUT) x best baseline at >= TPS_FLOOR x
# best baseline throughput
ACCEPT_TTFT_CUT = 0.15
ACCEPT_TPS_FLOOR = 0.99
# the threaded drive is real concurrency: thread interleaving perturbs
# every realized TTFT, so a single-sample p95 sits within noise of the
# acceptance bar.  The decision counters are near-deterministic run to
# run — the residual is timing noise, so each arm runs REPS times and
# the tail metrics are aggregated by MEDIAN (a single pathological rep —
# an OS hiccup mid-burst — must not drag the comparison the way a mean
# would)
THREADED_REPS = 7


def _med(sums, key: str) -> float:
    vals = sorted(s[key] for s in sums)
    return vals[len(vals) // 2]


def _deploy(policy: str):
    from repro.serving import DeploymentSpec
    d = DeploymentSpec(mode="dynamic_pd", colocated_instances=2,
                       colocated_chips=2)
    if policy == "slo_aware":
        d.admission_policy = "slo_aware"
    elif policy == "predictive":
        # max_wait_s=2.0: the starvation bound must sit ABOVE the queue
        # waits SJF is reordering (p95 >1s of virtual time under the
        # burst here, on either drive) or every pick degenerates to
        # oldest-first exactly when ordering matters; 2s still caps a
        # monster's extra delay near the blind baseline's p95
        d.dispatch_policy = "predicted_sjf"
        d.dispatch_knobs = {"max_wait_s": 2.0}
        d.cluster_policy = "jbsq"
        d.admission_policy = "predictive"
        d.admission_knobs = {"slack_factor": 2.0, "max_wait_s": 2.0}
        d.latency_predictor = "ridge_latency"
        d.length_predictor = "length_quantile"
        d.adaptive_chunking = True
    return d


def _tiered_burst(quick: bool):
    """The slo_attainment traffic at 2x rate for the 2-instance fleet:
    prefill-heavy, heavy-tailed, 10x MMPP flash crowd."""
    from repro.traffic import PromptClass, TrafficSpec, default_tiers
    classes = (PromptClass("chat", 256, 64),
               PromptClass("assist", 512, 64),
               PromptClass("rag", 2048, 64),
               PromptClass("summarize", 8192, 32))
    phases = ((1.0, 1.0), (4.0, 10.0)) if quick else ((4.0, 1.0), (4.0, 10.0))
    # zipf 2.0 makes the 8k summarize class RARE (~4% of arrivals) as well
    # as huge: the regime where SJF moves the p95 — the tail percentile
    # falls on mid-size requests that predictive ordering un-queues, while
    # the handful of monsters eat the (starvation-bounded) delay.  The
    # rate holds burst-crest waits around 1-1.5s: past the 2s starvation
    # bound SJF degenerates to oldest-first and the cut collapses
    spec = TrafficSpec(
        n=240 if quick else 480, rate=150.0 if quick else 110.0,
        arrival="mmpp", arrival_knobs={"phases": phases},
        classes=classes, zipf_alpha=2.0,
        tenants=default_tiers(ttft_scale=TTFT_SCALE))
    return spec.generate(0)


def _multi_turn(quick: bool):
    from repro.traffic import make_traffic
    return make_traffic("multi_turn", n=120 if quick else 360, rate=60.0,
                        conversations=8, turn_tokens=256, seed=3)


def run(quick: bool = False, drives=DRIVES, policies=DEFAULT_POLICIES,
        workloads=WORKLOADS):
    from repro.configs import get_config
    from repro.serving import Cluster, SimConfig

    cfg = get_config("qwen2-vl-2b")
    rows = []
    for drive in drives:
        for workload in workloads:
            # threaded drive always uses the smaller trace AND a 5x
            # slower clock: the RealTimeLoop paces virtual time at
            # time_scale wall-seconds per virtual second (arrivals and op
            # durations alike, so the offered load is identical), which
            # divides the host's real dispatch overhead and scheduler
            # noise by 5 in virtual terms — otherwise the p95 comparison
            # measures the host, not the policy
            q = quick or drive == "threaded"
            wl = _tiered_burst(q) if workload == "tiered_burst" \
                else _multi_turn(q)
            baselines = []
            reps = THREADED_REPS if drive == "threaded" else 1
            for policy in policies:
                # prefill_window=8 keeps several prefills router- and
                # daemon-visible, which is where predicted-SJF ordering
                # and JBSQ depth bounds have room to act
                sums = []
                for _ in range(reps):
                    cluster = Cluster(cfg, _deploy(policy),
                                      sim_cfg=SimConfig(max_num_seqs=64,
                                                        prefill_window=8),
                                      drive=drive,
                                      time_scale=0.5 if drive == "threaded"
                                      else 0.1)
                    res = cluster.run(copy.deepcopy(wl), until=36000)
                    if drive == "stepped":
                        cluster.check_kv_conservation()
                    sums.append(res)
                # counts (and the prediction section) come from ONE run so
                # every invariant (conservation, length.n == completed)
                # holds exactly; only the noisy tail metrics are averaged
                res = sums[-1]
                conserved = all(
                    s["completed"] + s["rejected"] + s["failed"]
                    == s["generated"] for s in sums)
                derived = {
                    "drive": drive,
                    "workload": workload,
                    "policy": policy,
                    "generated": res["generated"],
                    "completed": res["completed"],
                    "rejected": res["rejected"],
                    "conserved": bool(conserved),
                    "tokens_per_s": round(_med(sums, "output_tokens_per_s"), 0),
                    "ttft_p50_s": round(_med(sums, "ttft_p50_s"), 4),
                    "ttft_p95_s": round(_med(sums, "ttft_p95_s"), 4),
                    "ttft_p99_s": round(_med(sums, "ttft_p99_s"), 4),
                    "tpot_p99_s": round(_med(sums, "tpot_p99_s"), 5),
                }
                if reps > 1:
                    derived["reps"] = reps
                    derived["ttft_p95_reps"] = [
                        round(s["ttft_p95_s"], 4) for s in sums]
                if "tenants" in res:
                    derived["ttft_attainment"] = {
                        t: round(v["ttft_attainment"], 4)
                        for t, v in sorted(res["tenants"].items())}
                if policy != "predictive":
                    baselines.append(derived)
                else:
                    pred = res.get("prediction", {})
                    derived["prediction"] = pred
                    best_p95 = min(b["ttft_p95_s"] for b in baselines)
                    best_tps = max(b["tokens_per_s"] for b in baselines)
                    derived["ttft_p95_vs_best_baseline"] = round(
                        derived["ttft_p95_s"] / max(best_p95, 1e-9), 3)
                    derived["throughput_vs_best_baseline"] = "{:+.2%}".format(
                        derived["tokens_per_s"] / max(best_tps, 1e-9) - 1)
                    if workload == "tiered_burst":
                        # the PR's acceptance bar, recorded in the artifact
                        derived["meets_acceptance"] = bool(
                            derived["ttft_p95_s"]
                            <= (1 - ACCEPT_TTFT_CUT) * best_p95
                            and derived["tokens_per_s"]
                            >= ACCEPT_TPS_FLOOR * best_tps)
                rows.append((
                    f"predictive_sched.{drive}.{workload}.{policy}",
                    1e6 / max(_med(sums, "requests_per_s"), 1e-9), derived))
    return rows


def main(argv=None) -> None:
    import argparse

    from benchmarks._cli import emit_rows

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny trace, both drive modes")
    ap.add_argument("--drive", default="", choices=["", *DRIVES],
                    help="run one drive mode only (default: both)")
    ap.add_argument("--workloads", default=",".join(WORKLOADS),
                    help="comma-separated traffic shapes")
    ap.add_argument("--policies", default=",".join(DEFAULT_POLICIES),
                    help="comma-separated control-plane arms (the "
                         "non-predictive ones are the baselines)")
    ap.add_argument("--json", default="",
                    help="also write the rows to this JSON file")
    args = ap.parse_args(argv)
    drives = (args.drive,) if args.drive else DRIVES
    rows = run(quick=args.quick or args.smoke, drives=drives,
               policies=tuple(p for p in args.policies.split(",") if p),
               workloads=tuple(w for w in args.workloads.split(",") if w))
    emit_rows(rows, args.json)


if __name__ == "__main__":
    main()
