"""Cross-instance prefix reuse on shared-prefix chat traffic (v6).

``multi_turn`` traffic (``repro.traffic``: Zipf-picked conversations, one
shared system head, per-conversation growing histories) is the regime the
prefix tier targets: every turn re-sends the whole accumulated prompt, so
without reuse the cluster recomputes the same prefill FLOPs turn after
turn.  With ``prefix_cache="lru"`` plus ``prefix_affinity`` routing, a
turn lands on the instance already holding its conversation's pages and
prefills only the fresh suffix; when the router must place it elsewhere
(load floor), the cluster copies the missing pages over the KV transport
path instead of recomputing them whenever the cost model says copy < raw
compute.

Each drive runs the SAME trace through two configs:

  * ``cache_off``   — ``least_contended`` routing, ``prefix_cache="none"``
                      (the v5 baseline, bit-compatible with pre-v6 runs)
  * ``cache_on``    — ``prefix_affinity`` routing, ``prefix_cache="lru"``
  * ``cache_on_fetch`` — ``least_loaded`` routing + ``lru``: the router is
                      prefix-blind, so turns land on non-holders and the
                      cross-instance fetch path does the reuse (remote
                      fetch bytes > 0 while the hit rate stays high —
                      the cache tier composes with ANY routing policy)

plus a ``cache_on_fault`` leg that kills the affinity hot spot mid-trace:
the dead cache is wiped with its ledger, survivors absorb the work, and
KV conservation (checked at scheduled mid-run instants in EVERY leg,
including mid-fetch) still holds.

Expected (the PR's acceptance bar, asserted in each ``cache_on`` row's
derived JSON): ``prefix_affinity``+cache-on cuts mean TTFT by >= 20% vs
the cache-off baseline at equal-or-better token throughput — in BOTH
drive modes — with ``flops_saved > 0`` and the remote-fetch byte count
reported.
"""
from __future__ import annotations

import copy

DRIVES = ("stepped", "threaded")
# (row name, cluster routing policy, prefix cache policy)
VARIANTS = (
    ("cache_off", "least_contended", "none"),
    ("cache_on", "prefix_affinity", "lru"),
    ("cache_on_fetch", "least_loaded", "lru"),
    ("cache_on_fault", "prefix_affinity", "lru"),
)
INSTANCES = 3
CHIPS_PER_INSTANCE = 48


def _workload(quick: bool):
    """Prefill-bound shared-prefix chat: long system head + growing
    per-conversation histories at a rate that keeps prefill queues busy
    (TTFT must be prefill-compute-bound for reuse to show up in TTFT —
    at idle load the saved FLOPs hide behind queueing slack)."""
    from repro.traffic import make_traffic
    n = 80 if quick else 240
    return make_traffic("multi_turn", n=n, rate=40.0, conversations=6,
                        system_tokens=2048, turn_tokens=256,
                        output_tokens=32, seed=7)


def _cluster(drive: str, policy: str, cache: str):
    from repro.configs import get_config
    from repro.serving import Cluster, SimConfig, deployment_dynamic
    cfg = get_config("mixtral-8x7b")
    deploy = deployment_dynamic(total=INSTANCES * CHIPS_PER_INSTANCE,
                                instances=INSTANCES)
    deploy.cluster_policy = policy
    # chunked prefill keeps queued work router-visible (load() counts
    # daemon backlog, not the single executing op) so affinity's load
    # floor and the remote-fetch copy-vs-recompute decision both see
    # genuine queue depth
    sc = SimConfig(prefix_cache=cache, prefix_page_tokens=64,
                   chunk_prefill_tokens=1024)
    # threaded drive needs modeled op durations to dominate real dispatch
    # overhead (overhead divides by time_scale in modeled time) — same
    # rule as the role_switch / slo_attainment benchmarks
    scale = 0.1 if drive == "threaded" else 0.01
    return Cluster(cfg, deploy, sim_cfg=sc, drive=drive, time_scale=scale)


def run(quick: bool = False, drives=DRIVES):
    rows = []
    for drive in drives:
        wl = _workload(quick or drive == "threaded")
        horizon = max(r.arrival_time for r in wl)
        baseline = None
        for name, policy, cache in VARIANTS:
            if name == "cache_on_fault" and quick:
                continue
            cluster = _cluster(drive, policy, cache)
            # conservation probed at sampled mid-run instants — early
            # (first prefills + fetches in flight), mid-trace, and near
            # the arrival tail — not just at quiescence
            for frac in (0.05, 0.3, 0.6, 0.9):
                cluster.loop.at(frac * horizon,
                                cluster.check_kv_conservation)
            if name == "cache_on_fault":
                # kill C0 — the affinity hot spot holding the most cached
                # conversations — so the fault actually costs cached state
                cluster.loop.at(0.4 * horizon,
                                lambda c=cluster: c.fail_instance("C0"))
                cluster.loop.at(0.4 * horizon + 0.01,
                                cluster.check_kv_conservation)
            res = cluster.run(copy.deepcopy(wl), until=36000)
            cluster.check_kv_conservation()
            for inst in cluster.instances:
                inst.cache.check_invariants()
            pc = res.get("prefix_cache", {})
            derived = {
                "drive": drive,
                "variant": name,
                "policy": policy,
                "prefix_cache": cache,
                "generated": res["generated"],
                "completed": res["completed"],
                "failed": res["failed"],
                "conserved": True,        # every probe above would raise
                "ttft_mean_s": round(res["ttft_mean_s"], 4),
                "ttft_p95_s": round(res["ttft_p95_s"], 4),
                "tokens_per_s": round(res["output_tokens_per_s"], 0),
                "hit_rate": pc.get("hit_rate", 0.0),
                "flops_saved": pc.get("flops_saved", 0.0),
                "remote_fetches": pc.get("remote_fetches", 0),
                "remote_fetch_fails": pc.get("remote_fetch_fails", 0),
                "remote_fetch_bytes": pc.get("remote_fetch_bytes", 0.0),
                "evictions": pc.get("evictions", 0),
            }
            if name == "cache_off":
                baseline = derived
            else:
                improvement = 1.0 - (derived["ttft_mean_s"]
                                     / max(baseline["ttft_mean_s"], 1e-9))
                derived["ttft_improvement"] = round(improvement, 4)
                derived["throughput_vs_off"] = "{:+.2%}".format(
                    derived["tokens_per_s"]
                    / max(baseline["tokens_per_s"], 1e-9) - 1)
                if name == "cache_on":
                    # the PR's acceptance bar, recorded in the artifact
                    derived["meets_acceptance"] = bool(
                        improvement >= 0.20
                        and derived["flops_saved"] > 0
                        and derived["tokens_per_s"]
                        >= 0.99 * baseline["tokens_per_s"])
            rows.append((f"prefix_reuse.{drive}.{name}",
                         1e6 / max(res.get("requests_per_s", 0), 1e-9),
                         derived))
    return rows


def main(argv=None) -> None:
    import argparse

    from benchmarks._cli import emit_rows

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny trace, both drive modes")
    ap.add_argument("--drive", default="", choices=["", *DRIVES],
                    help="run one drive mode only (default: both)")
    ap.add_argument("--json", default="",
                    help="also write the rows to this JSON file")
    args = ap.parse_args(argv)
    drives = (args.drive,) if args.drive else DRIVES
    rows = run(quick=args.quick or args.smoke, drives=drives)
    emit_rows(rows, args.json)


if __name__ == "__main__":
    main()
