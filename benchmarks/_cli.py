"""Shared CLI plumbing for directly-runnable benchmark modules.

Keeps the row format in ONE place: the same ``name,us_per_call,derived``
CSV that benchmarks/run.py streams, plus the BENCH_*.json schema the CI
bench-smoke job uploads as artifacts.
"""
from __future__ import annotations

import json
import os


def rows_payload(rows):
    return [{"name": n, "us_per_call": u, "derived": d} for n, u, d in rows]


def emit_rows(rows, json_path: str = "") -> None:
    """Print the CSV rows; optionally also write them to a JSON file."""
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{json.dumps(json.dumps(derived))}")
    if json_path:
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump({"rows": rows_payload(rows)}, f, indent=2)
