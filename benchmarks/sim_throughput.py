"""Raw simulator throughput: events/sec and sim-ops/sec on a 384-chip run.

Every remaining experiment (predictive scheduling from traces, elastic
churn, DeepSeek-R1-scale sweeps) is bounded by how fast the stepped drive
chews through events — this benchmark makes that a first-class number and
CI gates on it (benchmarks/validate_artifacts.py fails bench-smoke when
``events_per_s`` goes missing, NaN, or regresses >30% below the recorded
floor).

Scenarios are ``examples/cluster_sim_384.py``-shaped: mixtral-8x7b on the
full 384-chip fleet, both deployments (FlexNPU dynamic 3x128 co-location —
the dispatch-policy-heavy path — and static 6P2D disaggregation — the
LinkModel/KV-streaming-heavy path), 1K-1K workload at rate 1e5.

Metrics per scenario:
  * ``events_per_s``  — event-loop callbacks executed per wall second;
  * ``ops_per_s``     — daemon ops completed per wall second (the
    simulated work actually retired, insensitive to how many loop
    events one op costs);
  * ``wall_s`` / ``sim_s`` — wall clock vs simulated seconds covered.

``BASELINE_EVENTS_PER_S`` records the pre-optimization numbers measured on
the same scenarios (PR 9's starting point, dev machine) so artifacts carry
the speedup factor; ``FLOOR_EVENTS_PER_S`` is the conservative regression
floor CI enforces (set well below a typical CI runner so machine variance
does not false-fail, but far above the pre-optimization baseline).
"""
from __future__ import annotations

import copy
import math
import time

# pre-PR baseline (events/sec, measured before the batched event loop /
# vectorized cost model landed) — recorded in every artifact row so the
# speedup factor is auditable
BASELINE_EVENTS_PER_S = {
    "dynamic.small": 6343.4,
    "dynamic.medium": 3441.0,
    "disagg.small": 6858.7,
    "disagg.medium": 5444.7,
}

# CI regression floor: validate_artifacts fails when measured events/sec
# drops more than 30% below this.  Deliberately conservative (CI runners
# are slower and noisier than the dev machine that recorded it).
FLOOR_EVENTS_PER_S = {
    "dynamic.small": 4000.0,
    "dynamic.medium": 4200.0,
    "disagg.small": 4000.0,
    "disagg.medium": 4400.0,
}

# (size, n_requests): medium is the ISSUE-9 acceptance scenario
SIZES = (("small", 120), ("medium", 600))


def _scenarios():
    from repro.serving import deployment_6p2d, deployment_dynamic
    return (("dynamic", deployment_dynamic()),
            ("disagg", deployment_6p2d()))


def _completed_ops(cluster) -> int:
    return sum(s.ops_completed
               for inst in cluster.instances
               for s in inst.daemon.profiler.stats.values())


def run(quick: bool = False, sizes=SIZES):
    from repro.configs import get_config
    from repro.serving import Cluster, SimConfig, make_workload

    cfg = get_config("mixtral-8x7b")
    if quick:
        sizes = tuple(s for s in sizes if s[0] == "small")
    rows = []
    for deploy_name, deploy in _scenarios():
        for size, n in sizes:
            wl = make_workload(n, 1024, 1024, rate=1e5, seed=3)
            cluster = Cluster(cfg, copy.deepcopy(deploy),
                              sim_cfg=SimConfig())
            t0 = time.perf_counter()
            res = cluster.run(copy.deepcopy(wl), until=72000)
            wall = time.perf_counter() - t0
            cluster.check_kv_conservation()
            key = f"{deploy_name}.{size}"
            events = cluster.loop.events
            ops = _completed_ops(cluster)
            ev_rate = events / wall if wall > 0 else math.nan
            baseline = BASELINE_EVENTS_PER_S.get(key, 0.0)
            derived = {
                "scenario": key,
                "requests": n,
                "completed": res["completed"],
                "events": events,
                "ops": ops,
                "wall_s": round(wall, 4),
                "sim_s": round(cluster.loop.clock.t, 3),
                "events_per_s": round(ev_rate, 1),
                "ops_per_s": round(ops / wall, 1) if wall > 0 else math.nan,
                "floor_events_per_s": FLOOR_EVENTS_PER_S.get(key, 0.0),
                "baseline_events_per_s": baseline,
            }
            if baseline > 0:
                derived["speedup_vs_baseline"] = round(ev_rate / baseline, 2)
            rows.append((f"sim_throughput.{key}", wall * 1e6 / max(events, 1),
                         derived))
    return rows


def main(argv=None) -> None:
    import argparse

    from benchmarks._cli import emit_rows

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small scenarios only")
    ap.add_argument("--medium", action="store_true",
                    help="run the medium (acceptance-gate) scenarios too")
    ap.add_argument("--json", default="",
                    help="also write the rows to this JSON file")
    args = ap.parse_args(argv)
    quick = (args.quick or args.smoke) and not args.medium
    rows = run(quick=quick)
    emit_rows(rows, args.json)


if __name__ == "__main__":
    main()
