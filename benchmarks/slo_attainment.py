"""Per-tier SLO attainment under a 10x flash crowd: tiered vs blind.

Multi-tenant traffic (``repro.traffic``: Zipf prompt-class mix over the
interactive/standard/batch tiers) arrives through an MMPP phase schedule
whose burst phase runs at 10x the base rate.  A tenant-blind control plane
admits FIFO, so burst-time batch/standard prefills queue ahead of
interactive requests and the interactive tier blows its tight TTFT SLO.
``slo_aware`` admission — strict priority, stride-weighted fairness within
a level, doomed-request shedding — keeps the interactive tier inside its
SLO on the SAME hardware at the same (or better) total throughput.

Expected (the PR's acceptance bar, asserted in each row's derived JSON):
interactive-tier p99 TTFT-SLO attainment >= 2x the tenant-blind baseline
under the 10x burst, at equal-or-better total token throughput — in BOTH
drive modes.  Every row also carries the honesty invariant
``completed + rejected == generated`` (the ``conserved`` key): shed
requests are first-class REJECTED results, never silent drops.  The
``slo_aware_shed`` policy variant bounds the waiting queue so shedding
actually fires and the rejection accounting is exercised end-to-end.
"""
from __future__ import annotations

import copy

DRIVES = ("stepped", "threaded")
DEFAULT_POLICIES = ("blind", "slo_aware", "slo_aware_shed")
# admission knobs per policy name ("blind" keeps the mode default — the
# tenant-blind FIFO ungated admission every pre-v5 deployment ran)
POLICY_KNOBS = {
    "blind": ("", {}),
    "slo_aware": ("slo_aware", {}),
    "slo_aware_shed": ("slo_aware", {"max_queue_depth": 40}),
}
# the interactive tier's TTFT target is 0.5s (ttft_scale=0.5 on the
# default tiers): tight enough that burst-time queueing breaks it
TTFT_SCALE = 0.5


def _spec(quick: bool):
    """Prefill-heavy tiered burst: TTFT is prefill-queue-bound here, so
    admission ORDER is what decides who meets the tight SLO (long-output
    mixes hide the effect behind decode backlog)."""
    from repro.traffic import PromptClass, TrafficSpec, default_tiers
    classes = (PromptClass("chat", 256, 64),
               PromptClass("assist", 512, 64),
               PromptClass("rag", 2048, 64),
               PromptClass("summarize", 4096, 32))
    # quick shortens the base phase so the small trace still reaches the
    # 10x burst (at n=160, a 4s base phase would absorb every arrival
    # before the flash crowd starts and nothing would queue)
    phases = ((1.0, 1.0), (4.0, 10.0)) if quick else ((4.0, 1.0), (4.0, 10.0))
    return TrafficSpec(
        n=160 if quick else 500, rate=40.0, arrival="mmpp",
        arrival_knobs={"phases": phases},
        classes=classes, zipf_alpha=1.1,
        tenants=default_tiers(ttft_scale=TTFT_SCALE))


def run(quick: bool = False, drives=DRIVES, policies=DEFAULT_POLICIES):
    from repro.configs import get_config
    from repro.serving import Cluster, DeploymentSpec, SimConfig

    cfg = get_config("qwen2-vl-2b")
    rows = []
    for drive in drives:
        # threaded drive always uses the smaller trace: real dispatch
        # overhead must stay below modeled op durations (same rule as the
        # role_switch benchmark)
        wl = _spec(quick or drive == "threaded").generate(0)
        baseline = None
        for policy in policies:
            adm, knobs = POLICY_KNOBS.get(policy, (policy, {}))
            deploy = DeploymentSpec(
                mode="dynamic_pd", colocated_instances=1, colocated_chips=2,
                admission_policy=adm, admission_knobs=knobs)
            # prefill_window=2 keeps burst backlog in the router-visible
            # waiting queue where admission ORDER applies (work already on
            # a daemon queue cannot be reordered)
            cluster = Cluster(cfg, deploy,
                              sim_cfg=SimConfig(max_num_seqs=64,
                                                prefill_window=2),
                              drive=drive, time_scale=0.1)
            res = cluster.run(copy.deepcopy(wl), until=36000)
            if drive == "stepped":
                cluster.check_kv_conservation()
            tiers = res["tenants"]
            conserved = (res["completed"] + res["rejected"] + res["failed"]
                         == res["generated"])
            derived = {
                "drive": drive,
                "policy": policy,
                "generated": res["generated"],
                "completed": res["completed"],
                "rejected": res["rejected"],
                "shed_requests": res.get("shed_requests", 0),
                "conserved": bool(conserved),
                "tokens_per_s": round(res["output_tokens_per_s"], 0),
                "slo_attainment": {
                    t: round(v["slo_attainment"], 4)
                    for t, v in sorted(tiers.items())},
                "ttft_attainment": {
                    t: round(v["ttft_attainment"], 4)
                    for t, v in sorted(tiers.items())},
                "ttft_p99_s": {t: round(v["ttft_p99_s"], 3)
                               for t, v in sorted(tiers.items())},
                "tpot_p99_s": {t: round(v["tpot_p99_s"], 4)
                               for t, v in sorted(tiers.items())},
                "admission": res["policy"].get("admission", {}),
            }
            if baseline is None:
                baseline = derived
            else:
                base_att = baseline["ttft_attainment"]["interactive"]
                this_att = derived["ttft_attainment"]["interactive"]
                ratio = this_att / max(base_att, 1e-9)
                derived["interactive_attainment_vs_blind"] = round(ratio, 3)
                derived["throughput_vs_blind"] = "{:+.2%}".format(
                    derived["tokens_per_s"]
                    / max(baseline["tokens_per_s"], 1e-9) - 1)
                if policy == "slo_aware":
                    # the PR's acceptance bar, recorded in the artifact
                    derived["meets_acceptance"] = bool(
                        ratio >= 2.0 and derived["tokens_per_s"]
                        >= 0.99 * baseline["tokens_per_s"])
            rows.append((f"slo_attainment.{drive}.{policy}",
                         1e6 / max(res["requests_per_s"], 1e-9), derived))
    return rows


def main(argv=None) -> None:
    import argparse

    from benchmarks._cli import emit_rows

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny trace, both drive modes")
    ap.add_argument("--drive", default="", choices=["", *DRIVES],
                    help="run one drive mode only (default: both)")
    ap.add_argument("--policies", default=",".join(DEFAULT_POLICIES),
                    help="comma-separated admission configs (first is the "
                         "tenant-blind comparison baseline)")
    ap.add_argument("--json", default="",
                    help="also write the rows to this JSON file")
    args = ap.parse_args(argv)
    drives = (args.drive,) if args.drive else DRIVES
    rows = run(quick=args.quick or args.smoke, drives=drives,
               policies=tuple(p for p in args.policies.split(",") if p))
    emit_rows(rows, args.json)


if __name__ == "__main__":
    main()
